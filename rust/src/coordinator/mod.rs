//! The training coordinator (Layer 3): owns data order, the LR schedule,
//! microbatching, telemetry, checkpoints, and the optimizer control
//! plane. Compute happens in the AOT XLA executables.
//!
//! Three execution modes (DESIGN.md §4):
//! * **Fused** — one `train_<opt>_<arch>` executable per step (fast path).
//! * **Host/DP** — `dp_ranks` simulated data-parallel workers each run
//!   `grad_<arch>` on their microbatch, a ring all-reduce averages the
//!   gradients, the host optimizer ([`opt::HostOpt`]) applies the update.
//! * **Disaggregated** — Host/DP plus the paper's 8-way optimizer-
//!   parallel Muon: Newton-Schulz jobs are sharded over `opt_ranks`
//!   workers, each calling the `ns_<m>x<n>` executable (Appendix A.1).

pub mod dp;
pub mod lr;
pub mod opt;
pub mod shard;


use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint;
use crate::config::TrainConfig;
use crate::data::{Loader, Split, TokenStream};
use crate::metrics::{PhaseProfiler, Record, Series, TelemetryWriter};
use crate::runtime::{Engine, Executable, HostValue};
use crate::tensor::Tensor;
use crate::util::threadpool::ThreadPool;

use lr::Trapezoid;
use opt::HostOpt;

/// Outcome summary of a training run.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    pub steps: u64,
    pub final_loss: f64,
    pub final_ppl: f64,
    pub final_kurt_max: f64,
    pub loss: Series,
    pub kurt_max: Series,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
}

enum Mode {
    Fused {
        train: Arc<Executable>,
        opt_state: Vec<Tensor>,
    },
    Host {
        grad: Arc<Executable>,
        host_opt: HostOpt,
        pool: Arc<ThreadPool>,
    },
}

pub struct Trainer {
    engine: Engine,
    pub cfg: TrainConfig,
    params: Vec<Tensor>,
    mode: Mode,
    evalq: Arc<Executable>,
    schedule: Trapezoid,
    loader: Loader,
    eval_batches: Vec<HostValue>,
    telemetry: Option<TelemetryWriter>,
    pub profiler: PhaseProfiler,
    n_layers: usize,
}

/// "off" levels value for the evalq quantization inputs (2^20 ~ fp16+).
pub const LEVELS_OFF: f32 = (1u32 << 20) as f32;

/// Smallest bit-width with a symmetric integer grid: below 2 bits,
/// `2^(b-1) - 1` levels is 0 (1-bit) or underflows the shift (0-bit),
/// and a 0-level scale poisons the evalq graph with a divide-by-zero.
pub const MIN_QUANT_BITS: u32 = 2;

/// levels = 2^(bits-1) - 1 as f32 (16+ = off). Infallible: bits below
/// [`MIN_QUANT_BITS`] clamp to the 2-bit grid as a last-resort guard —
/// validated entry points ([`checked_levels_for_bits`],
/// `eval::BitConfig::validate`, the CLI) reject them up front instead.
pub fn levels_for_bits(bits: u32) -> f32 {
    if bits >= 16 {
        LEVELS_OFF
    } else {
        (1u32 << (bits.max(MIN_QUANT_BITS) - 1)) as f32 - 1.0
    }
}

/// [`levels_for_bits`] that rejects unsupported widths instead of
/// clamping.
pub fn checked_levels_for_bits(bits: u32) -> Result<f32> {
    if bits < MIN_QUANT_BITS {
        bail!("unsupported bit-width {bits}: quantization needs at least \
               {MIN_QUANT_BITS} bits (16+ = off)");
    }
    Ok(levels_for_bits(bits))
}

/// Element-wise equal-weight mean of same-length vectors (the cross-rank
/// kurtosis-telemetry combine). Empty input or empty members yield an
/// empty vector.
pub fn mean_vecs(vs: &[Vec<f32>]) -> Vec<f32> {
    let Some(first) = vs.first() else {
        return Vec::new();
    };
    let mut out = first.clone();
    for v in &vs[1..] {
        debug_assert_eq!(v.len(), out.len(), "mean_vecs: ragged input");
        for (a, b) in out.iter_mut().zip(v) {
            *a += b;
        }
    }
    let inv = 1.0 / vs.len() as f32;
    for a in out.iter_mut() {
        *a *= inv;
    }
    out
}

impl Trainer {
    pub fn new(engine: Engine, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let m = engine.manifest();
        let arch = cfg.arch.clone();
        let n_layers = m.model.n_layers;
        let vocab = m.model.vocab_size;
        let (batch, seq) = (m.batch_train, m.model.seq_len);

        // Initialize params through the init artifact (same RNG as the
        // paper pipeline's jax init).
        let init = engine.load(&format!("init_{arch}"))?;
        let params: Vec<Tensor> = init
            .run(&[HostValue::tokens(&[1], vec![cfg.seed as i32])])?
            .into_iter()
            .map(|v| v.into_f32())
            .collect::<Result<_>>()?;

        let mode = if cfg.dp_ranks > 1 || cfg.disaggregated {
            let grad = engine
                .load(&format!("grad_{arch}"))
                .with_context(|| format!(
                    "host/disaggregated mode needs grad_{arch}; rebuild \
                     artifacts or use fused mode"))?;
            let mut host_opt = HostOpt::new(&cfg.optimizer, m.params(&arch)?);
            let pool = Arc::new(ThreadPool::new(
                cfg.dp_ranks.max(cfg.opt_ranks).max(1), 64));
            if cfg.disaggregated {
                install_disaggregated_ns(&engine, &mut host_opt,
                                         Arc::clone(&pool), cfg.opt_ranks)?;
            }
            Mode::Host { grad, host_opt, pool }
        } else {
            let train = engine.load(&format!("train_{}_{arch}",
                                             cfg.optimizer))?;
            let opt_state = crate::runtime::init_opt_state(
                m.opt_leaves(&arch, &cfg.optimizer)?);
            Mode::Fused { train, opt_state }
        };

        let evalq = engine.load(&format!("evalq_{arch}"))?;

        // Enough train batches for the whole run (+ accumulation).
        let max_batches =
            cfg.steps * (cfg.dp_ranks as u64 * cfg.grad_accum as u64).max(1)
            + 4;
        let loader = Loader::spawn(vocab, cfg.seed, Split::Train, batch, seq,
                                   8, max_batches);

        // Fixed held-out batches for perplexity (our WikiText-2).
        let mut valid = TokenStream::new(vocab, cfg.seed, Split::Valid, 0, 1);
        let eval_batches = (0..2)
            .map(|i| {
                let b = valid.next_batch(m.batch_eval, seq, i);
                HostValue::tokens(&[m.batch_eval, seq], b.tokens)
            })
            .collect();

        let schedule = Trapezoid::new(cfg.peak_lr, cfg.steps,
                                      cfg.warmup_frac, cfg.decay_frac);
        let telemetry = if cfg.run_dir.as_os_str().is_empty() {
            None
        } else {
            cfg.save(&cfg.run_dir)?;
            Some(TelemetryWriter::create(&cfg.run_dir.join("telemetry.jsonl"))?)
        };

        Ok(Trainer {
            engine,
            cfg,
            params,
            mode,
            evalq,
            schedule,
            loader,
            eval_batches,
            telemetry,
            profiler: PhaseProfiler::default(),
            n_layers,
        })
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }


    /// One training step. Returns (loss, kurt[2L]).
    pub fn step(&mut self, step_idx: u64) -> Result<(f64, Vec<f32>)> {
        let lr = self.schedule.at(step_idx) as f32;
        match &mut self.mode {
            Mode::Fused { train, opt_state } => {
                let tokens = {
                    let _g = self.profiler.span("data");
                    self.loader
                        .next()
                        .ok_or_else(|| anyhow!("data loader exhausted"))?
                };
                let tokens =
                    HostValue::tokens(&[tokens.batch, tokens.seq_len],
                                      tokens.tokens);
                let n_p = self.params.len();
                let n_o = opt_state.len();
                let _g = self.profiler.span("train_exec");
                let mut inputs: Vec<HostValue> = Vec::with_capacity(
                    n_p + n_o + 2);
                inputs.extend(self.params.iter().cloned().map(HostValue::F32));
                inputs.extend(opt_state.iter().cloned().map(HostValue::F32));
                inputs.push(tokens);
                inputs.push(HostValue::scalar(lr));
                let out = train.run(&inputs)?;
                for (dst, v) in self.params.iter_mut().zip(&out[..n_p]) {
                    *dst = v.as_f32()?.clone();
                }
                for (dst, v) in
                    opt_state.iter_mut().zip(&out[n_p..n_p + n_o])
                {
                    *dst = v.as_f32()?.clone();
                }
                let loss = out[n_p + n_o].as_f32()?.data()[0] as f64;
                let kurt = out[n_p + n_o + 1].as_f32()?.data().to_vec();
                Ok((loss, kurt))
            }
            Mode::Host { grad, host_opt, pool } => {
                // Collect dp_ranks * grad_accum microbatches.
                let n_micro = self.cfg.dp_ranks * self.cfg.grad_accum;
                let mut micro = Vec::with_capacity(n_micro);
                {
                    let _g = self.profiler.span("data");
                    for _ in 0..n_micro {
                        let b = self.loader.next().ok_or_else(|| {
                            anyhow!("data loader exhausted")
                        })?;
                        micro.push(HostValue::tokens(
                            &[b.batch, b.seq_len], b.tokens));
                    }
                }
                let n_p = self.params.len();
                // Per-rank: run grad_accum microbatches, locally average.
                let params: Vec<HostValue> = self
                    .params
                    .iter()
                    .cloned()
                    .map(HostValue::F32)
                    .collect();
                let accum = self.cfg.grad_accum;
                let grad_exe = Arc::clone(grad);
                let params = Arc::new(params);
                let rank_inputs: Vec<Vec<HostValue>> = micro
                    .chunks(accum)
                    .map(|c| c.to_vec())
                    .collect();
                let t0 = Instant::now();
                let rank_results: Vec<Result<(Vec<f32>, f64, Vec<f32>)>> =
                    pool.scatter(rank_inputs, move |_i, batches| {
                        let mut flat: Option<Vec<f32>> = None;
                        let mut loss_sum = 0.0f64;
                        // Kurtosis telemetry averages over *every*
                        // microbatch (keeping only the last one skewed
                        // Host-mode kurt_max/kurt_mean away from the
                        // fused executable's whole-batch semantics).
                        let mut kurt_sum: Vec<f32> = Vec::new();
                        for tokens in batches {
                            let mut inputs: Vec<HostValue> =
                                params.as_ref().clone();
                            inputs.push(tokens);
                            let out = grad_exe.run(&inputs)?;
                            loss_sum +=
                                out[n_p].as_f32()?.data()[0] as f64;
                            let k = out[n_p + 1].as_f32()?.data();
                            if kurt_sum.is_empty() {
                                kurt_sum = k.to_vec();
                            } else {
                                for (a, b) in kurt_sum.iter_mut().zip(k) {
                                    *a += b;
                                }
                            }
                            let mut g: Vec<f32> = Vec::new();
                            for v in &out[..n_p] {
                                g.extend_from_slice(v.as_f32()?.data());
                            }
                            match &mut flat {
                                None => flat = Some(g),
                                Some(acc) => {
                                    for (a, b) in acc.iter_mut().zip(&g) {
                                        *a += b;
                                    }
                                }
                            }
                        }
                        let mut g = flat.unwrap();
                        let inv = 1.0 / accum as f32;
                        for v in g.iter_mut() {
                            *v *= inv;
                        }
                        for v in kurt_sum.iter_mut() {
                            *v *= inv;
                        }
                        Ok((g, loss_sum / accum as f64, kurt_sum))
                    });
                self.profiler.add("grad_exec", t0.elapsed().as_secs_f64());

                let mut flats = Vec::with_capacity(self.cfg.dp_ranks);
                let mut loss = 0.0f64;
                let mut rank_kurts = Vec::with_capacity(self.cfg.dp_ranks);
                for r in rank_results {
                    let (g, l, k) = r?;
                    flats.push(g);
                    loss += l;
                    rank_kurts.push(k);
                }
                loss /= self.cfg.dp_ranks as f64;
                // Equal-weight mean across ranks (each rank already
                // averaged its microbatches): kurt telemetry now covers
                // all dp_ranks * grad_accum microbatches, matching
                // fused-mode semantics instead of reporting whichever
                // rank's vector happened to be assigned last.
                let kurt = mean_vecs(&rank_kurts);

                let t1 = Instant::now();
                let reduced = dp::ring_all_reduce(flats);
                self.profiler.add("all_reduce", t1.elapsed().as_secs_f64());

                // Unflatten rank 0's result into grad tensors.
                let t2 = Instant::now();
                let mut grads = Vec::with_capacity(n_p);
                let mut off = 0usize;
                for p in &self.params {
                    let n = p.len();
                    grads.push(Tensor::new(p.shape().to_vec(),
                                           reduced[0][off..off + n].to_vec()));
                    off += n;
                }
                host_opt.apply(&mut self.params, &grads, lr)?;
                self.profiler.add("opt_apply", t2.elapsed().as_secs_f64());
                Ok((loss, kurt))
            }
        }
    }

    /// Held-out perplexity + kurtosis at the current params (fp path).
    pub fn evaluate(&mut self) -> Result<(f64, Vec<f32>)> {
        let _g = self.profiler.span("eval");
        let mut nll = 0.0f64;
        let mut count = 0.0f64;
        // Same telemetry semantics as the Host/DP step fix: average the
        // kurt vector over every eval batch, not just the last one.
        let mut kurt_batches: Vec<Vec<f32>> = Vec::new();
        for tokens in &self.eval_batches {
            let mut inputs: Vec<HostValue> = self
                .params
                .iter()
                .cloned()
                .map(HostValue::F32)
                .collect();
            inputs.push(tokens.clone());
            inputs.push(HostValue::scalar(LEVELS_OFF));
            inputs.push(HostValue::scalar(LEVELS_OFF));
            inputs.push(HostValue::scalar(0.0));
            let out = self.evalq.run(&inputs)?;
            nll += out[0].as_f32()?.data()[0] as f64;
            count += out[1].as_f32()?.data()[0] as f64;
            kurt_batches.push(out[2].as_f32()?.data().to_vec());
        }
        Ok(((nll / count).exp(), mean_vecs(&kurt_batches)))
    }

    /// Run the configured number of steps with telemetry + checkpoints.
    pub fn run(&mut self) -> Result<TrainSummary> {
        let t0 = Instant::now();
        let mut loss_series = Series::default();
        let mut kurt_series = Series::default();
        let mut last_loss = f64::NAN;
        let m_seq = self.engine.manifest().model.seq_len;
        let m_batch = self.engine.manifest().batch_train;

        for step in 0..self.cfg.steps {
            let (loss, kurt) = self.step(step)?;
            if !loss.is_finite() {
                bail!("loss diverged (NaN/inf) at step {step}");
            }
            last_loss = loss;
            let kmax = kurt.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let kmean =
                kurt.iter().sum::<f32>() as f64 / kurt.len().max(1) as f64;
            loss_series.push(step, loss);
            kurt_series.push(step, kmax);
            if let Some(w) = &mut self.telemetry {
                w.write(
                    &Record::new(step)
                        .field("loss", loss)
                        .field("lr", self.schedule.at(step))
                        .field("kurt_max", kmax)
                        .field("kurt_mean", kmean)
                        .tag("phase", "train"),
                )?;
            }
            let do_eval = self.cfg.eval_every > 0
                && (step + 1) % self.cfg.eval_every == 0;
            if do_eval {
                let (ppl, ekurt) = self.evaluate()?;
                let ekmax =
                    ekurt.iter().cloned().fold(f32::MIN, f32::max) as f64;
                if let Some(w) = &mut self.telemetry {
                    w.write(
                        &Record::new(step)
                            .field("valid_ppl", ppl)
                            .field("valid_kurt_max", ekmax)
                            .tag("phase", "eval"),
                    )?;
                    w.flush()?;
                }
            }
            if self.cfg.ckpt_every > 0 && (step + 1) % self.cfg.ckpt_every == 0
            {
                self.save_checkpoint(step + 1)?;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        self.save_checkpoint(self.cfg.steps)?;
        let (final_ppl, final_kurt) = self.evaluate()?;
        let final_kurt_max =
            final_kurt.iter().cloned().fold(f32::MIN, f32::max) as f64;
        if let Some(w) = &mut self.telemetry {
            w.write(
                &Record::new(self.cfg.steps)
                    .field("valid_ppl", final_ppl)
                    .field("valid_kurt_max", final_kurt_max)
                    .tag("phase", "final"),
            )?;
            w.flush()?;
        }
        let micro = (self.cfg.dp_ranks * self.cfg.grad_accum).max(1) as f64;
        let tokens =
            self.cfg.steps as f64 * micro * (m_batch * m_seq) as f64;
        Ok(TrainSummary {
            steps: self.cfg.steps,
            final_loss: last_loss,
            final_ppl,
            final_kurt_max,
            loss: loss_series,
            kurt_max: kurt_series,
            wall_secs: wall,
            tokens_per_sec: tokens / wall.max(1e-9),
        })
    }

    pub fn save_checkpoint(&self, step: u64) -> Result<()> {
        if self.cfg.run_dir.as_os_str().is_empty() {
            return Ok(());
        }
        let m = self.engine.manifest();
        let specs = m.params(&self.cfg.arch)?;
        let opt_leaves;
        let opt_pair = match &self.mode {
            Mode::Fused { opt_state, .. } => {
                opt_leaves =
                    m.opt_leaves(&self.cfg.arch, &self.cfg.optimizer)?;
                Some((opt_leaves, opt_state.as_slice()))
            }
            Mode::Host { .. } => None,
        };
        checkpoint::save(&self.cfg.run_dir, step, &self.cfg.arch,
                         &self.cfg.optimizer, specs, &self.params, opt_pair)?;
        Ok(())
    }

    /// Layers in the model (kurt vector is 2x this).
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }
}

/// Wire the paper's optimizer-parallel Newton-Schulz into a HostOpt:
/// NS jobs are sharded round-robin over `opt_ranks` pool workers, each
/// executing the matching `ns_<m>x<n>` XLA artifact (gradients partitioned
/// across dedicated optimizer ranks, Appendix A.1).
pub fn install_disaggregated_ns(engine: &Engine, host_opt: &mut HostOpt,
                                pool: Arc<ThreadPool>,
                                _opt_ranks: usize) -> Result<()> {
    let engine = engine.clone();
    host_opt.ns_fn = Box::new(move |jobs| {
        let items: Vec<(usize, Tensor)> = jobs.to_vec();
        let engine = engine.clone();
        let results = pool.scatter(items, move |_r, (idx, g)| {
            let (m, n) = (g.shape()[0], g.shape()[1]);
            let exe = engine.load(&format!("ns_{m}x{n}"))?;
            let out = exe.run(&[HostValue::F32(g)])?;
            Ok::<(usize, Tensor), anyhow::Error>(
                (idx, out.into_iter().next().unwrap().into_f32()?))
        });
        results.into_iter().collect()
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_mapping() {
        assert_eq!(levels_for_bits(4), 7.0);
        assert_eq!(levels_for_bits(8), 127.0);
        assert_eq!(levels_for_bits(3), 3.0);
        assert_eq!(levels_for_bits(16), LEVELS_OFF);
        assert_eq!(levels_for_bits(32), LEVELS_OFF);
    }

    /// Regression: bits 0 panicked on shift underflow and bits 1
    /// produced 0 levels (an evalq divide-by-zero); now both clamp to
    /// the 2-bit grid while the checked variant rejects them.
    #[test]
    fn degenerate_bits_clamp_and_checked_rejects() {
        assert_eq!(levels_for_bits(0), 1.0);
        assert_eq!(levels_for_bits(1), 1.0);
        assert_eq!(levels_for_bits(2), 1.0);
        assert!(levels_for_bits(0) > 0.0);
        assert!(checked_levels_for_bits(0).is_err());
        assert!(checked_levels_for_bits(1).is_err());
        assert_eq!(checked_levels_for_bits(2).unwrap(), 1.0);
        assert_eq!(checked_levels_for_bits(16).unwrap(), LEVELS_OFF);
    }

    /// Regression for the Host/DP kurt telemetry: the step used to keep
    /// only the last microbatch's kurt per rank and the last rank's
    /// vector overall. The combine now equal-weight-averages across all
    /// ranks (each rank pre-averages its microbatches), so the reported
    /// vector matches the mean over every microbatch — what fused mode's
    /// whole-batch kurtosis approximates.
    #[test]
    fn mean_vecs_averages_across_ranks() {
        // Two ranks, two microbatches each, already rank-averaged.
        let r0 = vec![1.0f32, 10.0]; // rank 0: mean of [0,2] and [2,18]
        let r1 = vec![3.0f32, 30.0];
        let m = mean_vecs(&[r0.clone(), r1.clone()]);
        assert_eq!(m, vec![2.0, 20.0]);
        // Not the last-rank vector the bug reported.
        assert_ne!(m, r1);
        // Degenerate shapes.
        assert_eq!(mean_vecs(&[]), Vec::<f32>::new());
        assert_eq!(mean_vecs(&[vec![5.0]]), vec![5.0]);
    }
}
