//! Host-side optimizers for the data-parallel and disaggregated modes:
//! the Muon outer loop (momentum, scaling, weight decay) and Adam for the
//! decoupled embedding/norm leaves (Section 3.3).
//!
//! Math mirrors python/compile/optimizers.py exactly; the integration
//! suite pins host steps against the fused train_* artifacts.

use anyhow::Result;

use crate::runtime::manifest::ParamSpec;
use crate::tensor::linalg;
use crate::tensor::{par, Tensor};

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.95;
pub const ADAM_EPS: f32 = 1e-8;
pub const MUON_MOMENTUM: f32 = 0.95;
pub const WEIGHT_DECAY: f32 = 0.01;
/// lr_adam = ADAM_LR_RATIO * lr inside Muon (matches the L2 constant).
pub const ADAM_LR_RATIO: f32 = 10.0;
pub const NS_STEPS: usize = 5;

/// How each parameter leaf is treated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafRole {
    /// Newton-Schulz orthogonalized (Muon's matrix path).
    Muon,
    /// Element-wise Adam with weight decay.
    AdamDecayed,
    /// Element-wise Adam without decay (norm scales).
    AdamPlain,
}

/// Partition rule shared with python's `_partition` (optimizers.py).
pub fn leaf_role(optimizer: &str, spec: &ParamSpec) -> LeafRole {
    let matrixish = spec.kind == "matrix"
        || (optimizer == "muon_noadam"
            && (spec.kind == "embed" || spec.kind == "unembed"));
    match (optimizer, matrixish) {
        ("muon" | "muon_noadam", true) => LeafRole::Muon,
        _ if spec.kind == "norm" => LeafRole::AdamPlain,
        _ => LeafRole::AdamDecayed,
    }
}

/// Host-side optimizer state (one entry per param leaf).
pub struct HostOpt {
    pub optimizer: String,
    roles: Vec<LeafRole>,
    /// Muon momentum buffers (None for adam leaves).
    muon_buf: Vec<Option<Tensor>>,
    adam_m: Vec<Option<Tensor>>,
    adam_v: Vec<Option<Tensor>>,
    pub step: u64,
    /// Plug-in Newton-Schulz: host linalg by default; the disaggregated
    /// mode swaps in the ns_* XLA executables sharded over ranks.
    pub ns_fn: Box<dyn Fn(&[(usize, Tensor)]) -> Result<Vec<(usize, Tensor)>>
                     + Send + Sync>,
}

impl HostOpt {
    pub fn new(optimizer: &str, specs: &[ParamSpec]) -> HostOpt {
        assert!(optimizer == "adam" || optimizer.starts_with("muon"),
                "host optimizer supports adam/muon, got {optimizer}");
        let roles: Vec<LeafRole> =
            specs.iter().map(|s| leaf_role(optimizer, s)).collect();
        let muon_buf = specs
            .iter()
            .zip(&roles)
            .map(|(s, r)| (*r == LeafRole::Muon)
                 .then(|| Tensor::zeros(&s.shape)))
            .collect();
        let adam_m = specs
            .iter()
            .zip(&roles)
            .map(|(s, r)| (*r != LeafRole::Muon)
                 .then(|| Tensor::zeros(&s.shape)))
            .collect();
        let adam_v = specs
            .iter()
            .zip(&roles)
            .map(|(s, r)| (*r != LeafRole::Muon)
                 .then(|| Tensor::zeros(&s.shape)))
            .collect();
        HostOpt {
            optimizer: optimizer.to_string(),
            roles,
            muon_buf,
            adam_m,
            adam_v,
            step: 0,
            // Host Newton-Schulz path: one scatter job per Muon leaf on
            // the shared pool. With a single leaf the map stays on the
            // caller thread and the inner matmuls parallelize instead
            // (the kernels' nested-dispatch guard makes the two
            // arrangements mutually exclusive).
            ns_fn: Box::new(|jobs| {
                Ok(par::par_map(par::active_pool(), jobs, |_, (i, g)| {
                    (*i, linalg::ns_orthogonalize(g, NS_STEPS))
                }))
            }),
        }
    }

    pub fn roles(&self) -> &[LeafRole] {
        &self.roles
    }

    /// Apply one optimizer step in place. `lr` is the schedule value.
    pub fn apply(&mut self, params: &mut [Tensor], grads: &[Tensor],
                 lr: f32) -> Result<()> {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.roles.len());
        self.step += 1;
        let t = self.step as f32;
        let lr_adam = if self.optimizer == "adam" {
            lr
        } else {
            lr * ADAM_LR_RATIO
        };

        // Phase 1: momentum update + collect NS jobs (Muon leaves).
        let mut ns_jobs: Vec<(usize, Tensor)> = Vec::new();
        for (i, role) in self.roles.iter().enumerate() {
            if *role != LeafRole::Muon {
                continue;
            }
            let buf = self.muon_buf[i].as_mut().unwrap();
            // buf = mu*buf + g ; ns_input = g + mu*buf (nesterov)
            let g = &grads[i];
            let mut new_buf = buf.clone().scale(MUON_MOMENTUM);
            new_buf.axpy(1.0, g);
            *buf = new_buf;
            let mut ns_in = g.clone();
            ns_in.axpy(MUON_MOMENTUM, buf);
            ns_jobs.push((i, ns_in));
        }

        // Phase 2: orthogonalize (host linalg or sharded executables).
        let ns_out = (self.ns_fn)(&ns_jobs)?;

        // Phase 3: apply updates.
        for (i, u) in ns_out {
            let (n_in, n_out) =
                (params[i].shape()[0] as f32, params[i].shape()[1] as f32);
            let scale = (n_out / n_in).max(1.0).sqrt();
            let p = &mut params[i];
            let mut next = p.clone().scale(1.0 - lr * WEIGHT_DECAY);
            next.axpy(-(lr * scale), &u);
            *p = next;
        }
        for (i, role) in self.roles.iter().enumerate() {
            if *role == LeafRole::Muon {
                continue;
            }
            let wd = if *role == LeafRole::AdamDecayed {
                WEIGHT_DECAY
            } else {
                0.0
            };
            let m = self.adam_m[i].as_mut().unwrap();
            let v = self.adam_v[i].as_mut().unwrap();
            let g = &grads[i];
            let p = &mut params[i];
            let bc1 = 1.0 - ADAM_B1.powf(t);
            let bc2 = 1.0 - ADAM_B2.powf(t);
            let (pd, md, vd, gd) =
                (p.data_mut(), m.data_mut(), v.data_mut(), g.data());
            for j in 0..gd.len() {
                md[j] = ADAM_B1 * md[j] + (1.0 - ADAM_B1) * gd[j];
                vd[j] = ADAM_B2 * vd[j] + (1.0 - ADAM_B2) * gd[j] * gd[j];
                let mhat = md[j] / bc1;
                let vhat = vd[j] / bc2;
                pd[j] = pd[j] * (1.0 - lr_adam * wd)
                    - lr_adam * mhat / (vhat.sqrt() + ADAM_EPS);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn spec(name: &str, shape: &[usize], kind: &str) -> ParamSpec {
        ParamSpec { name: name.into(), shape: shape.to_vec(),
                    init: "normal".into(), kind: kind.into() }
    }

    fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed, 1);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 0.5);
        t
    }

    #[test]
    fn roles_match_partition_rule() {
        let specs = [
            spec("embed", &[16, 8], "embed"),
            spec("w", &[8, 8], "matrix"),
            spec("norm", &[8], "norm"),
        ];
        assert_eq!(leaf_role("muon", &specs[0]), LeafRole::AdamDecayed);
        assert_eq!(leaf_role("muon", &specs[1]), LeafRole::Muon);
        assert_eq!(leaf_role("muon", &specs[2]), LeafRole::AdamPlain);
        assert_eq!(leaf_role("muon_noadam", &specs[0]), LeafRole::Muon);
        assert_eq!(leaf_role("adam", &specs[1]), LeafRole::AdamDecayed);
    }

    #[test]
    fn adam_step_direction() {
        let specs = [spec("w", &[2, 2], "matrix")];
        let mut opt = HostOpt::new("adam", &specs);
        let mut params = vec![Tensor::zeros(&[2, 2])];
        let grads = vec![Tensor::new(vec![2, 2], vec![1., -1., 2., -2.])];
        opt.apply(&mut params, &grads, 0.1).unwrap();
        // First step of Adam moves ~ -lr * sign(g).
        let p = params[0].data();
        assert!(p[0] < -0.09 && p[1] > 0.09, "{p:?}");
        assert_eq!(opt.step, 1);
    }

    #[test]
    fn muon_matrix_gets_orthogonalized_update() {
        let specs = [spec("w", &[8, 8], "matrix"), spec("e", &[4, 8], "embed")];
        let mut opt = HostOpt::new("muon", &specs);
        let mut params = vec![Tensor::zeros(&[8, 8]), Tensor::zeros(&[4, 8])];
        let grads = vec![randn(&[8, 8], 3), randn(&[4, 8], 4)];
        opt.apply(&mut params, &grads, 0.01).unwrap();
        // Matrix update ~ -lr * orth(...): singular values near lr.
        let p = &params[0];
        let gram = linalg::matmul(&linalg::transpose(p), p);
        for i in 0..8 {
            let d = gram.at2(i, i).sqrt();
            assert!((0.002..0.03).contains(&d), "sigma {d}");
        }
        // Embedding leaf moved via Adam (non-zero).
        assert!(params[1].frobenius_norm() > 1e-4);
    }

    #[test]
    fn momentum_accumulates_across_steps() {
        let specs = [spec("w", &[4, 4], "matrix")];
        let mut opt = HostOpt::new("muon", &specs);
        let mut params = vec![Tensor::zeros(&[4, 4])];
        let g = randn(&[4, 4], 5);
        opt.apply(&mut params, &[g.clone()], 0.01).unwrap();
        let b1 = opt.muon_buf[0].as_ref().unwrap().frobenius_norm();
        opt.apply(&mut params, &[g.clone()], 0.01).unwrap();
        let b2 = opt.muon_buf[0].as_ref().unwrap().frobenius_norm();
        assert!(b2 > b1);
    }

    #[test]
    fn custom_ns_fn_is_used() {
        let specs = [spec("w", &[4, 4], "matrix")];
        let mut opt = HostOpt::new("muon", &specs);
        opt.ns_fn = Box::new(|jobs| {
            Ok(jobs.iter().map(|(i, g)| (*i, g.clone().scale(0.0))).collect())
        });
        let mut params = vec![Tensor::full(&[4, 4], 1.0)];
        let grads = vec![randn(&[4, 4], 6)];
        opt.apply(&mut params, &grads, 0.1).unwrap();
        // Update was zeroed: only weight decay moved the params.
        for v in params[0].data() {
            assert!((v - (1.0 - 0.1 * WEIGHT_DECAY)).abs() < 1e-6);
        }
    }
}
