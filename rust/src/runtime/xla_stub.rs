//! Offline stand-in for the vendored `xla` PJRT bindings.
//!
//! The coordinator was written against the `xla` crate (PJRT CPU client
//! + HLO-text compilation), which only exists in the online vendor set —
//! this tree must build and run its host-side paths (tensor/quant/infer/
//! data, and every test that calls `engine_or_skip`) without it. This
//! module mirrors the handful of `xla::` items `runtime` touches:
//!
//! * [`Literal`] is fully functional host-side (typed payload + dims),
//!   so `HostValue` round-trips — and their tests — work unchanged.
//! * [`PjRtClient::cpu`] succeeds (manifest-driven host paths like
//!   `quant::prepare` and `osp generate` need an [`super::Engine`]), but
//!   [`PjRtClient::compile`] and everything downstream return a clear
//!   "offline stub" error, so artifact execution fails fast instead of
//!   pretending.
//!
//! Swapping the real bindings back in = add the `xla` dependency and
//! delete the `#[path]` module declaration in `runtime/mod.rs`; the call
//! sites are API-compatible.

use std::fmt;
use std::path::Path;

/// Error type standing in for `xla::Error` (converts into
/// `anyhow::Error` at the call sites).
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn offline(what: &str) -> XlaError {
    XlaError(format!(
        "{what} requires the vendored `xla` PJRT bindings, which are not \
         part of this offline build (see runtime/xla_stub.rs)"))
}

type Result<T> = std::result::Result<T, XlaError>;

/// Typed host payload of a [`Literal`].
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can carry (mirrors the binding's
/// `NativeType`).
pub trait Element: Sized + Clone {
    fn to_payload(data: &[Self]) -> Payload;
    fn from_payload(p: &Payload) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }

    fn from_payload(p: &Payload) -> Result<Vec<Self>> {
        match p {
            Payload::F32(v) => Ok(v.clone()),
            Payload::I32(_) => Err(XlaError("literal is i32, not f32".into())),
        }
    }
}

impl Element for i32 {
    fn to_payload(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }

    fn from_payload(p: &Payload) -> Result<Vec<Self>> {
        match p {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F32(_) => Err(XlaError("literal is f32, not i32".into())),
        }
    }
}

/// Host-side literal: functional (unlike the execution types below) so
/// `HostValue` conversion round-trips offline.
#[derive(Clone, Debug)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { payload: T::to_payload(data),
                  dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel < 0 || numel as usize != self.payload.len() {
            return Err(XlaError(format!(
                "reshape {:?} != {} elements", dims, self.payload.len())));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(offline("untupling an execution result"))
    }
}

/// Parsed HLO module (never constructible offline).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(offline(&format!("parsing HLO text {:?}", path.as_ref())))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by execution (never constructible
/// offline).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(offline("fetching a device buffer"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(offline("executing a compiled artifact"))
    }
}

/// CPU client handle. Construction succeeds so `Engine::open` works for
/// the manifest-driven host paths; compilation is where offline stops.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(offline("compiling an HLO computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape_check() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[1i32, 2]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn execution_surface_errors_offline() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable
                .execute(&[0u8])
                .is_err());
        assert!(Literal::vec1(&[0.0f32]).to_tuple().is_err());
    }
}
