//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once on the CPU
//! client, execute from the coordinator's hot path.
//!
//! Mirrors /opt/xla-example/load_hlo: HLO *text* interchange (the image's
//! xla_extension 0.5.1 rejects jax>=0.5 serialized protos), tuple results
//! unpacked by the manifest's output specs. Python never runs here.

pub mod manifest;

/// The `xla::` paths below resolve to the offline stub (functional
/// host-side literals, fail-fast compile/execute) — the vendored PJRT
/// bindings are not part of this build. See `xla_stub.rs` for the swap
/// procedure when they are.
#[path = "xla_stub.rs"]
mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, Dtype, Manifest, OptLeafSpec, ParamSpec,
                   TensorSpec};

use crate::tensor::Tensor;

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(Vec<usize>, Vec<i32>),
}

impl HostValue {
    pub fn scalar(v: f32) -> HostValue {
        HostValue::F32(Tensor::new(vec![1], vec![v]))
    }

    pub fn tokens(shape: &[usize], data: Vec<i32>) -> HostValue {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32(shape.to_vec(), data)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32(s, _) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            HostValue::I32(..) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            HostValue::I32(..) => bail!("expected f32 value, got i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64>;
        let lit = match self {
            HostValue::F32(t) => {
                dims = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            HostValue::I32(shape, data) => {
                dims = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostValue> {
        match spec.dtype {
            Dtype::F32 => {
                let data = lit.to_vec::<f32>().with_context(|| {
                    format!("output '{}' not f32", spec.name)
                })?;
                if data.len() != spec.numel() {
                    bail!("output '{}': got {} elems, manifest says {:?}",
                          spec.name, data.len(), spec.shape);
                }
                let shape = if spec.shape.is_empty() {
                    vec![1]
                } else {
                    spec.shape.clone()
                };
                Ok(HostValue::F32(Tensor::new(shape, data)))
            }
            Dtype::I32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(HostValue::I32(spec.shape.clone(), data))
            }
        }
    }
}

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execution statistics (for §Perf profiling).
    pub stats: Mutex<ExecStats>,
}

// The xla crate's wrappers hold `Rc` handles, so they are !Send/!Sync even
// though the underlying C++ PJRT objects are thread-safe. All PJRT entry
// points in this module go through EXEC_LOCK (the device is a single CPU
// stream anyway), which also serializes the Rc refcount traffic the
// wrapper types generate internally — making the shared use sound.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

/// Global PJRT serialization lock (see the safety note above). Worker
/// threads stay structurally parallel (scatter/all-reduce/channels); only
/// the accelerator queue is serialized, as on a real single-device node.
static EXEC_LOCK: Mutex<()> = Mutex::new(());

#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

impl Executable {
    /// Validate inputs against the manifest, run, unpack the tuple result.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!("artifact '{}': {} inputs given, {} expected",
                  self.spec.name, inputs.len(), self.spec.inputs.len());
        }
        for (v, s) in inputs.iter().zip(&self.spec.inputs) {
            let numel: usize = v.shape().iter().product();
            if numel != s.numel() {
                bail!("artifact '{}', input '{}': shape {:?} != manifest {:?}",
                      self.spec.name, s.name, v.shape(), s.shape);
            }
        }
        let t0 = Instant::now();
        let parts = {
            let _lock = EXEC_LOCK.lock().unwrap();
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|v| v.to_literal())
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing '{}'", self.spec.name))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            tuple.to_tuple().context("untupling result")?
        };
        if parts.len() != self.spec.outputs.len() {
            bail!("artifact '{}': {} outputs, manifest says {}",
                  self.spec.name, parts.len(), self.spec.outputs.len());
        }
        let out = parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostValue::from_literal(lit, spec))
            .collect::<Result<Vec<_>>>()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut st = self.stats.lock().unwrap();
        st.calls += 1;
        st.total_secs += dt;
        Ok(out)
    }
}

/// The PJRT engine: one CPU client + a lazy compile cache keyed by
/// artifact name. Clone-cheap via Arc; safe to share across the
/// coordinator's worker threads (PJRT execution is thread-safe; the
/// compile cache is mutex-guarded).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

/// One compile cache entry. The per-name mutex is what makes `load`
/// compile-once under concurrency: the first caller compiles while
/// holding its slot, same-name callers block on the slot (not on the
/// whole cache map), other names proceed independently.
type CacheSlot = Mutex<Option<Arc<Executable>>>;

struct EngineInner {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<CacheSlot>>>,
}

// xla::PjRtClient wraps a thread-safe C++ client; the raw pointer fields
// make the rust type !Send by default.
unsafe impl Send for EngineInner {}
unsafe impl Sync for EngineInner {}

impl Engine {
    /// Open the artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            inner: Arc::new(EngineInner {
                client,
                dir: dir.to_path_buf(),
                manifest,
                cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.inner.dir
    }

    /// Load + compile an artifact (cached). Compilation happens once per
    /// artifact name, even under concurrent first requests: the old
    /// check-then-insert dropped the cache lock between lookup and
    /// insert, so two threads racing on an uncached name both compiled
    /// it. Now each name owns a slot mutex held across compilation —
    /// the loser of the race blocks on the slot and receives the
    /// winner's executable; requests for other names never wait.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        let slot = {
            let mut cache = self.inner.cache.lock().unwrap();
            Arc::clone(cache.entry(name.to_string()).or_default())
        };
        let mut entry = slot.lock().unwrap();
        if let Some(e) = entry.as_ref() {
            return Ok(Arc::clone(e));
        }
        let spec = self.inner.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let exe = {
            // PJRT entry point: serialize on EXEC_LOCK like `run` (the
            // wrapper types' internal Rc traffic — see the safety note
            // on `Executable`).
            let _lock = EXEC_LOCK.lock().unwrap();
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .with_context(|| {
                    format!("parsing HLO text {:?}", spec.file)
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.inner
                .client
                .compile(&comp)
                .with_context(|| format!("compiling '{name}'"))?
        };
        let compiled = Arc::new(Executable {
            spec,
            exe,
            stats: Mutex::new(ExecStats::default()),
        });
        eprintln!("[runtime] compiled {name} in {:.2}s",
                  t0.elapsed().as_secs_f64());
        // A failed compile leaves the slot empty, so a later call
        // retries instead of caching the error.
        *entry = Some(Arc::clone(&compiled));
        Ok(compiled)
    }

    /// Time spent inside PJRT per loaded artifact (for §Perf). Snapshots
    /// the slot handles first so the cache map is never held while
    /// waiting on a slot mid-compile (which would stall unrelated
    /// `load` calls).
    pub fn exec_stats(&self) -> Vec<(String, ExecStats)> {
        let slots: Vec<(String, Arc<CacheSlot>)> = self
            .inner
            .cache
            .lock()
            .unwrap()
            .iter()
            .map(|(k, slot)| (k.clone(), Arc::clone(slot)))
            .collect();
        slots
            .into_iter()
            .filter_map(|(k, slot)| {
                let entry = slot.lock().unwrap();
                entry.as_ref().map(|e| (k, *e.stats.lock().unwrap()))
            })
            .collect()
    }
}

/// Build the initial optimizer state from manifest init kinds.
pub fn init_opt_state(leaves: &[OptLeafSpec]) -> Vec<Tensor> {
    leaves
        .iter()
        .map(|l| match l.init.as_str() {
            "eye" => Tensor::eye(l.shape[0]),
            _ => Tensor::zeros(&l.shape),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_roundtrip_f32() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = HostValue::F32(t.clone());
        let lit = v.to_literal().unwrap();
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 3],
                                dtype: Dtype::F32 };
        let back = HostValue::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_f32().unwrap().data(), t.data());
    }

    #[test]
    fn host_value_roundtrip_i32() {
        let v = HostValue::tokens(&[2, 2], vec![1, 2, 3, 4]);
        let lit = v.to_literal().unwrap();
        let spec = TensorSpec { name: "t".into(), shape: vec![2, 2],
                                dtype: Dtype::I32 };
        match HostValue::from_literal(&lit, &spec).unwrap() {
            HostValue::I32(shape, data) => {
                assert_eq!(shape, vec![2, 2]);
                assert_eq!(data, vec![1, 2, 3, 4]);
            }
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn scalar_helper() {
        let v = HostValue::scalar(3.5);
        assert_eq!(v.shape(), &[1]);
        assert_eq!(v.as_f32().unwrap().data(), &[3.5]);
    }

    #[test]
    fn init_opt_state_kinds() {
        let leaves = vec![
            OptLeafSpec { name: "step".into(), shape: vec![1],
                          init: "zeros".into() },
            OptLeafSpec { name: "q".into(), shape: vec![3, 3],
                          init: "eye".into() },
        ];
        let st = init_opt_state(&leaves);
        assert_eq!(st[0].data(), &[0.0]);
        assert_eq!(st[1].at2(1, 1), 1.0);
        assert_eq!(st[1].at2(0, 1), 0.0);
    }
}
