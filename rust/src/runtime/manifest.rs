//! artifacts/manifest.json model: the contract between python/compile
//! (which writes it) and the Rust coordinator (which is entirely
//! manifest-driven — no hard-coded shapes anywhere in L3).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// One input or output tensor of an artifact.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO executable.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// A model parameter leaf (ordering = calling convention).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub kind: String,
}

/// An optimizer-state leaf. init is "zeros" | "eye".
#[derive(Clone, Debug)]
pub struct OptLeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

/// Model configuration as resolved at lowering time.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub rope_theta: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub kernels: String,
    pub model: ModelCfg,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub batch_probe: usize,
    pub probe_layers: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// arch name -> ordered param leaves
    pub param_specs: BTreeMap<String, Vec<ParamSpec>>,
    /// arch name -> optimizer name -> ordered opt-state leaves
    pub opt_specs: BTreeMap<String, BTreeMap<String, Vec<OptLeafSpec>>>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: j.req("name")?.as_str().context("name")?.to_string(),
        shape: j.req("shape")?.usize_arr().context("shape")?,
        dtype: Dtype::parse(j.req("dtype")?.as_str().unwrap_or("f32"))?,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let mc = j.req("model_config")?;
        let model = ModelCfg {
            vocab_size: mc.req("vocab_size")?.as_usize().context("vocab")?,
            d_model: mc.req("d_model")?.as_usize().context("d_model")?,
            n_layers: mc.req("n_layers")?.as_usize().context("n_layers")?,
            n_heads: mc.req("n_heads")?.as_usize().context("n_heads")?,
            d_ff: mc.req("d_ff")?.as_usize().context("d_ff")?,
            seq_len: mc.req("seq_len")?.as_usize().context("seq_len")?,
            // Present in every manifest the compiler writes; default for
            // hand-rolled test manifests predating the field.
            rope_theta: mc
                .get("rope_theta")
                .and_then(|j| j.as_f64())
                .unwrap_or(10000.0),
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.req("file")?.as_str().context("file")?),
                    inputs,
                    outputs,
                },
            );
        }

        let mut param_specs = BTreeMap::new();
        for (arch, arr) in j.req("param_specs")?.as_obj().context("p")? {
            let specs = arr
                .as_arr()
                .context("param_specs arr")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req("name")?.as_str().context("n")?.into(),
                        shape: p.req("shape")?.usize_arr().context("s")?,
                        init: p.req("init")?.as_str().context("i")?.into(),
                        kind: p.req("kind")?.as_str().context("k")?.into(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            param_specs.insert(arch.clone(), specs);
        }

        let mut opt_specs = BTreeMap::new();
        for (arch, opts) in j.req("opt_specs")?.as_obj().context("o")? {
            let mut per_opt = BTreeMap::new();
            for (opt, arr) in opts.as_obj().context("opt obj")? {
                let leaves = arr
                    .as_arr()
                    .context("opt arr")?
                    .iter()
                    .map(|p| {
                        Ok(OptLeafSpec {
                            name: p.req("name")?.as_str().context("n")?.into(),
                            shape: p.req("shape")?.usize_arr().context("s")?,
                            init: p.req("init")?.as_str().context("i")?.into(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                per_opt.insert(opt.clone(), leaves);
            }
            opt_specs.insert(arch.clone(), per_opt);
        }

        Ok(Manifest {
            preset: j.req("preset")?.as_str().context("preset")?.into(),
            kernels: j.req("kernels")?.as_str().unwrap_or("pallas").into(),
            model,
            batch_train: j.req("batch_train")?.as_usize().context("bt")?,
            batch_eval: j.req("batch_eval")?.as_usize().context("be")?,
            batch_probe: j.req("batch_probe")?.as_usize().context("bp")?,
            probe_layers: j.req("probe_layers")?.usize_arr().context("pl")?,
            artifacts,
            param_specs,
            opt_specs,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})",
                                   self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn params(&self, arch: &str) -> Result<&[ParamSpec]> {
        self.param_specs
            .get(arch)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("unknown arch '{arch}'"))
    }

    pub fn opt_leaves(&self, arch: &str, opt: &str) -> Result<&[OptLeafSpec]> {
        self.opt_specs
            .get(arch)
            .and_then(|m| m.get(opt))
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("unknown arch/opt '{arch}/{opt}'"))
    }

    /// Total parameter count for an architecture.
    pub fn param_count(&self, arch: &str) -> Result<usize> {
        Ok(self.params(arch)?.iter().map(|p| p.shape.iter().product::<usize>()).sum())
    }

    /// Optimizer state element count (the Table-1 memory column).
    pub fn opt_state_count(&self, arch: &str, opt: &str) -> Result<usize> {
        Ok(self
            .opt_leaves(arch, opt)?
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "preset": "tiny", "kernels": "pallas",
      "model_config": {"vocab_size": 256, "d_model": 64, "n_layers": 2,
        "n_heads": 2, "d_ff": 176, "seq_len": 64, "rope_theta": 10000.0,
        "norm": "rms", "embproj": false, "init_std": 0.02},
      "batch_train": 8, "batch_eval": 8, "batch_probe": 2,
      "probe_layers": [0, 1],
      "archs": {"rmsnorm_plain": {"norm": "rms", "embproj": false}},
      "param_specs": {"rmsnorm_plain": [
        {"name": "embed", "shape": [256, 64], "init": "normal",
         "kind": "embed"}]},
      "opt_specs": {"rmsnorm_plain": {"adam": [
        {"name": "step", "shape": [1], "init": "zeros"},
        {"name": "adam_m.embed", "shape": [256, 64], "init": "zeros"}]}},
      "artifacts": {"ns_64x64": {"file": "ns_64x64.hlo.txt",
        "hash": "abc",
        "inputs": [{"name": "g", "shape": [64, 64], "dtype": "f32"}],
        "outputs": [{"name": "orth", "shape": [64, 64], "dtype": "f32"}]}}
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("osp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.model.d_model, 64);
        let a = m.artifact("ns_64x64").unwrap();
        assert_eq!(a.inputs[0].shape, vec![64, 64]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(m.params("rmsnorm_plain").unwrap()[0].kind, "embed");
        assert_eq!(m.param_count("rmsnorm_plain").unwrap(), 256 * 64);
        assert_eq!(m.opt_state_count("rmsnorm_plain", "adam").unwrap(),
                   1 + 256 * 64);
        assert!(m.artifact("nope").is_err());
        assert!(m.params("nope").is_err());
    }
}
