//! Compatibility shim: the quantized KV cache moved into the shared
//! host model layer (`rust/src/model/kv.rs`, DESIGN.md §9) so the block
//! forward, the decode scheduler, and the engine-free evaluator all use
//! one store. Existing `infer::kv::...` paths keep working through this
//! re-export.

pub use crate::model::kv::*;
