//! Continuous-batching decode scheduler (DESIGN.md §8-§9).
//!
//! [`DecodeEngine`] owns a FIFO of [`GenRequest`]s and a set of active
//! sequences capped at `max_batch`. Every [`DecodeEngine::step`] feeds
//! one *block* per active sequence through the shared
//! [`InferModel::forward_block`]: sequences still consuming their prompt
//! feed up to [`DecodeParams::prefill_chunk`] tokens at once (chunked
//! prefill — each packed weight row's in-register dequant is amortized
//! across the whole chunk, exactly like `qmatmul_rhs` amortizes across
//! the batch, and block-dequant attention decodes each cached KV row
//! once per chunk instead of once per prompt token — DESIGN.md §10),
//! while sequences that are decoding feed one token. The
//! step then samples where the prompt is exhausted, evicts finished
//! sequences, and admits queued ones, so the batch stays full at *step*
//! granularity.
//!
//! Robustness: bad requests are rejected with `Err` instead of a panic —
//! [`DecodeEngine::submit`] validates prompts against the vocab, and the
//! model layer itself returns `Err` on empty batches or out-of-vocab
//! tokens — so one malformed request can never kill the serve loop.
//! [`DecodeEngine::cancel`] evicts a request mid-decode (deadline
//! expiry, client disconnect — DESIGN.md §12) without disturbing its
//! batchmates: per-sequence caches and RNGs mean the survivors' streams
//! are bit-identical to a run where the cancelled request was never
//! admitted (pinned by `rust/tests/infer_properties.rs`).
//!
//! Determinism: a sequence's stream depends only on (model, its own
//! prompt, decode params, its own sampling RNG) — per-row kernels and
//! per-sequence attention make results independent of batch composition,
//! worker count, *and prefill chunk size* (pinned by
//! `rust/tests/infer_properties.rs` and `rust/tests/model_properties.rs`).
//! That independence extends across *processes*: a model whose trunk
//! linears were swapped for row-parallel remote stubs
//! ([`InferModel::shard_remote`], DESIGN.md §14) produces bit-identical
//! streams for any shard count, because col shards concatenate exact
//! f32 stripes and row shards sum exact i32 partials before the single
//! rescale (pinned by `rust/tests/shard_properties.rs`).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::model::kv::{PagePool, PoolGauges, SeqKv, DEFAULT_PAGE_ROWS};
use crate::model::{sample_token_filtered, InferModel, LogitsMode, SeqBlock};
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;

/// Default prompt-ingestion block size (`--prefill-chunk`).
pub const DEFAULT_PREFILL_CHUNK: usize = 64;

/// Runtime decode configuration.
#[derive(Clone, Copy, Debug)]
pub struct DecodeParams {
    /// Activation fake-quant bits (16 = off), like the evalq input.
    pub a_bits: u32,
    /// KV-cache storage bits (16 = f32 passthrough).
    pub kv_bits: u32,
    /// Active-sequence cap (the batching knob).
    pub max_batch: usize,
    /// <= 0 is greedy argmax.
    pub temperature: f32,
    /// Keep only the `top_k` highest-probability tokens (0 = off).
    pub top_k: usize,
    /// Nucleus truncation: smallest probability mass kept (>= 1.0 = off).
    pub top_p: f32,
    /// Max prompt tokens fed per sequence per step (>= 1; chunk 1
    /// reproduces the old one-token-per-step prefill bit-exactly).
    pub prefill_chunk: usize,
    /// Base seed; each request samples from `seed ^ request id`.
    pub seed: u64,
    /// Rows per KV page (`--kv-page-rows`; DESIGN.md §13). Any value
    /// >= 1 is bit-identical; sharing needs `n_heads` to divide it.
    pub kv_page_rows: usize,
    /// Soft KV pool budget in MiB (`--kv-pool-mb`; 0 = unbounded).
    /// Enforced by admission control, never by allocation.
    pub kv_pool_mb: usize,
    /// Copy-on-write prefix sharing across requests
    /// (`--share-prefix`). Off by default: shared streams are pinned
    /// bit-identical to unshared ones, but the library default stays
    /// conservative like `IntMode`.
    pub share_prefix: bool,
}

impl DecodeParams {
    pub fn greedy(a_bits: u32, kv_bits: u32, max_batch: usize)
                  -> DecodeParams {
        DecodeParams { a_bits, kv_bits, max_batch, temperature: 0.0,
                       top_k: 0, top_p: 1.0,
                       prefill_chunk: DEFAULT_PREFILL_CHUNK, seed: 0,
                       kv_page_rows: DEFAULT_PAGE_ROWS, kv_pool_mb: 0,
                       share_prefix: false }
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// A finished request: the prompt plus `generated` new tokens.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: usize,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
}

struct Active {
    id: usize,
    /// Prompt followed by generated tokens.
    tokens: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    cache: SeqKv,
    rng: Pcg,
    /// Prefix pages offered to the pool registry (once per request).
    registered: bool,
}

impl Active {
    fn n_generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    fn done(&self) -> bool {
        self.n_generated() >= self.max_new
    }
}

/// Totals of one engine run (the serve-bench numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    /// Forward tokens processed (prefill + decode positions).
    pub tokens_processed: u64,
    /// Prompt tokens ingested (the prefill phase of every request).
    pub tokens_prefilled: u64,
    /// Newly generated tokens.
    pub tokens_generated: u64,
    pub steps: u64,
    pub wall_secs: f64,
    /// Requests evicted via [`DecodeEngine::cancel`] (deadline expiry or
    /// client disconnect), queued or active.
    pub cancelled: u64,
    /// Peak physical KV bytes in the page pool (shared pages counted
    /// once — DESIGN.md §13).
    pub peak_kv_bytes: usize,
    /// Peak distinct physical KV pages in the pool.
    pub kv_pages_peak: usize,
    /// Peak page references saved by prefix sharing
    /// (`refs_live - pages_live` high-water mark; 0 with sharing off).
    pub kv_pages_shared: usize,
    /// Integer-kernel backend the model's linears resolved to for this
    /// run's `a_bits` (None = f32 LUT path).
    pub int_kernel: Option<&'static str>,
    /// Row-parallel worker count when the model's trunk linears are
    /// remote stubs (DESIGN.md §14); 0 = all weights local.
    pub remote_workers: usize,
}

impl DecodeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_processed as f64 / self.wall_secs.max(1e-9)
    }

    pub fn generated_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_secs.max(1e-9)
    }

    /// Prompt tokens ingested per second (the prefill-throughput
    /// serve-bench column).
    pub fn prefill_per_sec(&self) -> f64 {
        self.tokens_prefilled as f64 / self.wall_secs.max(1e-9)
    }
}

pub struct DecodeEngine<'m, 'p> {
    model: &'m InferModel,
    params: DecodeParams,
    pool: Option<&'p ThreadPool>,
    /// Page pool every admitted sequence's cache draws from
    /// (DESIGN.md §13). Private to this engine, so the `Drop` balance
    /// assert can demand zero outstanding refs.
    kv_pool: Arc<PagePool>,
    queue: VecDeque<GenRequest>,
    active: Vec<Active>,
    finished: Vec<GenResult>,
    /// `(request id, token)` pairs sampled by the most recent step, in
    /// batch order — the per-token streaming surface `osp serve` reads.
    emitted: Vec<(usize, i32)>,
    pub stats: DecodeStats,
}

impl<'m, 'p> DecodeEngine<'m, 'p> {
    pub fn new(model: &'m InferModel, params: DecodeParams,
               pool: Option<&'p ThreadPool>) -> DecodeEngine<'m, 'p> {
        assert!(params.max_batch > 0, "max_batch must be positive");
        let stats = DecodeStats {
            int_kernel: model.int_kernel_label(params.a_bits),
            remote_workers: model.remote_workers(),
            ..DecodeStats::default()
        };
        let kv_pool = PagePool::with_budget_mb(
            model.cfg.head_dim(), params.kv_bits,
            params.kv_page_rows.max(1), params.kv_pool_mb);
        DecodeEngine { model, params, pool, kv_pool,
                       queue: VecDeque::new(), active: Vec::new(),
                       finished: Vec::new(), emitted: Vec::new(),
                       stats }
    }

    /// The engine's KV page pool (page-size/sharing-aware tests build
    /// caches against it; serve reads gauges via
    /// [`DecodeEngine::pool_gauges`]).
    pub fn kv_pool(&self) -> &Arc<PagePool> {
        &self.kv_pool
    }

    /// Instantaneous page-pool gauges (`/metrics`, serve-bench rows).
    pub fn pool_gauges(&self) -> PoolGauges {
        self.kv_pool.gauges()
    }

    /// Drop the prefix-sharing registry, returning its page refs to
    /// the pool — drain-time leak accounting calls this before
    /// demanding `refs_live == pages_live == 0`.
    pub fn clear_prefix_cache(&self) {
        self.kv_pool.clear_prefixes();
    }

    /// Worst-case whole-lifetime page footprint of a `tokens`-token
    /// sequence (one K and one V store per layer; ignores sharing, so
    /// admission control stays conservative).
    fn pages_needed(&self, tokens: usize) -> usize {
        let rows = tokens * self.model.cfg.n_heads;
        2 * self.model.cfg.n_layers * self.kv_pool.pages_for_rows(rows)
    }

    /// Whether the pool can hold a whole `(prompt + max_new)`-token
    /// sequence *right now*. Always true without a `--kv-pool-mb`
    /// budget; serve turns `false` into 503 backpressure while other
    /// sequences are running (an idle engine admits regardless — see
    /// [`DecodeEngine::step`]'s registry-reclaim progress guarantee).
    pub fn pool_has_room(&self, prompt_len: usize, max_new: usize)
                         -> bool {
        let g = self.kv_pool.gauges();
        g.cap_pages == 0
            || g.pages_live + self.pages_needed(prompt_len + max_new)
                <= g.cap_pages
    }

    /// Enqueue a request (admitted at the next step with a free slot).
    /// Empty prompts are given a BOS-like token 0 so position 0 exists.
    /// Prompts carrying out-of-vocab tokens are rejected with `Err`
    /// before they can enter a batch — already-queued and active
    /// requests are unaffected.
    pub fn submit(&mut self, mut req: GenRequest) -> Result<()> {
        if req.prompt.is_empty() {
            req.prompt.push(0);
        }
        let vocab = self.model.cfg.vocab_size;
        for &t in &req.prompt {
            if t < 0 || t as usize >= vocab {
                bail!("request {}: prompt token {t} outside vocab 0..{vocab}",
                      req.id);
            }
        }
        let cap = self.kv_pool.gauges().cap_pages;
        if cap > 0 {
            let need = self.pages_needed(req.prompt.len() + req.max_new);
            if need > cap {
                bail!("request {}: worst case needs {need} KV pages, \
                       pool budget is {cap} pages", req.id);
            }
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn n_pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Sequences currently occupying a batch slot.
    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Requests admitted to the engine but not yet in a batch slot.
    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    /// Evict a request wherever it lives — still queued, or active
    /// mid-decode. Its batch slot and KV cache are freed immediately; no
    /// [`GenResult`] is produced. Batchmates are untouched: per-sequence
    /// caches, RNGs, and attention mean the survivors' streams stay
    /// bit-identical to a run where this request was never admitted.
    /// Returns false when the id is unknown (already finished or never
    /// submitted) — cancelling twice is harmless.
    pub fn cancel(&mut self, id: usize) -> bool {
        if let Some(i) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(i);
            self.stats.cancelled += 1;
            return true;
        }
        if let Some(i) = self.active.iter().position(|a| a.id == id) {
            let a = self.active.remove(i);
            Self::teardown(a);
            self.stats.cancelled += 1;
            return true;
        }
        false
    }

    /// The one sequence-teardown path (DESIGN.md §13): every way an
    /// active sequence leaves the engine — finishing, `cancel`
    /// (deadline expiry or client disconnect), or engine drop —
    /// funnels its `Active` through here, so the batch slot and every
    /// KV page ref it holds are returned at a single point and pool
    /// balance is provable from any exit path. Returns
    /// `(id, prompt_len, generated)` for the finish path; cancel
    /// paths drop the triple.
    fn teardown(a: Active) -> (usize, usize, Vec<i32>) {
        let Active { id, prompt_len, tokens, cache, .. } = a;
        // Dropping the cache releases its page refs through the pool
        // (see `QRows::drop`) — eagerly, so slot and pages free
        // together.
        drop(cache);
        let generated = tokens[prompt_len.min(tokens.len())..].to_vec();
        (id, prompt_len, generated)
    }

    /// Tokens sampled by the most recent [`DecodeEngine::step`], as
    /// `(request id, token)` in batch order. Draining is optional —
    /// the buffer is rebuilt each step — but a streaming serve loop
    /// calls this after every step to push tokens out as they are
    /// sampled.
    pub fn take_emitted(&mut self) -> Vec<(usize, i32)> {
        std::mem::take(&mut self.emitted)
    }

    /// Requests that finished since the last drain (unsorted — eviction
    /// order). [`DecodeEngine::run`] drains the same buffer, so use one
    /// or the other.
    pub fn take_finished(&mut self) -> Vec<GenResult> {
        std::mem::take(&mut self.finished)
    }

    fn admit(&mut self) {
        while self.active.len() < self.params.max_batch {
            let Some(req) = self.queue.front() else { break };
            let g = self.kv_pool.gauges();
            if g.cap_pages > 0 {
                let need =
                    self.pages_needed(req.prompt.len() + req.max_new);
                if g.pages_live + need > g.cap_pages {
                    if !self.active.is_empty() {
                        // Defer: running sequences will finish and
                        // return pages.
                        break;
                    }
                    // Engine is idle, so nothing will free pages on
                    // its own — reclaim the prefix registry and admit
                    // anyway (the budget is soft; `submit` already
                    // rejected requests that can never fit).
                    self.kv_pool.clear_prefixes();
                }
            }
            let req = self.queue.pop_front().expect("front checked");
            let mut cache =
                self.model.new_cache_in(self.params.kv_bits,
                                        &self.kv_pool);
            if self.params.share_prefix {
                if let Some((tok, groups)) = self
                    .kv_pool
                    .lookup_prefix(&req.prompt, self.model.cfg.n_heads)
                {
                    cache.adopt_prefix(tok, groups);
                }
            }
            self.active.push(Active {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: req.prompt,
                max_new: req.max_new,
                cache,
                rng: Pcg::new(self.params.seed ^ req.id as u64, 77),
                registered: false,
            });
        }
    }

    /// One engine step: admit, feed one block per active sequence
    /// (prefill chunks for prompt tokens, single tokens for decode),
    /// sample where the prompt is exhausted, evict finished sequences.
    /// Returns the number of tokens processed (0 = idle).
    pub fn step(&mut self) -> Result<usize> {
        let t0 = Instant::now();
        self.emitted.clear();
        self.admit();
        if self.active.is_empty() {
            return Ok(0);
        }
        let chunk = self.params.prefill_chunk.max(1);
        // Each sequence feeds the tokens at its cache position: the
        // remaining known tokens, capped at the prefill chunk. Logits
        // from the last known token produce the next sample. A sequence
        // samples only while it still owes tokens (`max_new` 0 must
        // generate nothing), and the logits head runs on last-token rows
        // only — skipped entirely on pure-prefill steps where nobody
        // samples.
        let feeds: Vec<(usize, usize)> = self
            .active
            .iter()
            .map(|a| {
                let pos = a.cache.n_tokens();
                (pos, (a.tokens.len() - pos).min(chunk))
            })
            .collect();
        let will: Vec<bool> = self
            .active
            .iter()
            .zip(&feeds)
            .map(|(a, &(pos, n))| {
                pos + n == a.tokens.len() && a.n_generated() < a.max_new
            })
            .collect();
        let want_logits = will.iter().any(|&w| w);
        let (model, pool, a_bits) = (self.model, self.pool,
                                     self.params.a_bits);
        let logits = {
            let mut blocks: Vec<SeqBlock> = self
                .active
                .iter_mut()
                .zip(&feeds)
                .map(|(a, &(pos, n))| SeqBlock {
                    tokens: &a.tokens[pos..pos + n],
                    cache: &mut a.cache,
                })
                .collect();
            let mode = if want_logits { LogitsMode::Last } else {
                LogitsMode::None
            };
            model.forward_block(pool, &mut blocks, a_bits, mode, None)?
        };
        if let Some(logits) = logits {
            let vocab = self.model.cfg.vocab_size;
            for (r, a) in self.active.iter_mut().enumerate() {
                if will[r] {
                    let row = &logits.data()[r * vocab..(r + 1) * vocab];
                    let next = sample_token_filtered(
                        row, self.params.temperature, self.params.top_k,
                        self.params.top_p, &mut a.rng);
                    a.tokens.push(next);
                    self.emitted.push((a.id, next));
                }
            }
        }
        // Offer fully-prefilled whole-page prefixes to the pool
        // registry so later requests with the same prompt head adopt
        // the pages instead of re-prefilling (DESIGN.md §13). Prefill
        // is deterministic, so a registered page's bytes equal what
        // the adopter would have computed — the bit-parity contract.
        if self.params.share_prefix {
            let nh = self.model.cfg.n_heads;
            for a in &mut self.active {
                if a.registered {
                    continue;
                }
                let share = self
                    .kv_pool
                    .shareable_prefix_len(a.prompt_len, nh);
                if share == 0 {
                    a.registered = true;
                    continue;
                }
                if a.cache.n_tokens() >= share {
                    a.cache.register_prefix(&a.tokens[..share]);
                    a.registered = true;
                }
            }
        }
        let g = self.kv_pool.gauges();
        self.stats.peak_kv_bytes =
            self.stats.peak_kv_bytes.max(g.bytes_peak);
        self.stats.kv_pages_peak = g.pages_peak;
        self.stats.kv_pages_shared = g.shared_peak;
        let processed: usize = feeds.iter().map(|&(_pos, n)| n).sum();
        self.stats.tokens_processed += processed as u64;
        for (a, &(pos, n)) in self.active.iter().zip(&feeds) {
            // Fed tokens at positions below prompt_len are prompt tokens.
            self.stats.tokens_prefilled +=
                a.prompt_len.min(pos + n).saturating_sub(pos) as u64;
        }
        self.stats.steps += 1;
        // Evict in place, keeping submission order within `finished`
        // resolution by id later.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done() {
                let a = self.active.remove(i);
                let (id, prompt_len, generated) = Self::teardown(a);
                self.stats.tokens_generated += generated.len() as u64;
                self.finished.push(GenResult { id, prompt_len,
                                               generated });
            } else {
                i += 1;
            }
        }
        self.stats.wall_secs += t0.elapsed().as_secs_f64();
        Ok(processed)
    }

    /// Drive until every submitted request finishes; results sorted by
    /// request id.
    pub fn run(&mut self) -> Result<Vec<GenResult>> {
        while self.n_pending() > 0 {
            self.step()?;
        }
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

impl Drop for DecodeEngine<'_, '_> {
    /// Tear down all remaining sequences through the one shared path
    /// and assert pool balance: with every cache dropped and the
    /// prefix registry cleared, the engine-private pool must hold zero
    /// live refs and zero live pages, or some exit path leaked.
    fn drop(&mut self) {
        for a in std::mem::take(&mut self.active) {
            Self::teardown(a);
        }
        self.queue.clear();
        self.kv_pool.clear_prefixes();
        let g = self.kv_pool.gauges();
        debug_assert_eq!(
            (g.refs_live, g.pages_live), (0, 0),
            "engine drop leaked KV pages: {} refs, {} live",
            g.refs_live, g.pages_live);
    }
}

/// Decode `prompts` to completion under `params`; returns the generated
/// tokens per prompt (order matches input). The one-call entry point the
/// consistency checks and `osp generate` use. Errs on malformed prompts
/// instead of panicking.
pub fn generate(model: &InferModel, prompts: &[Vec<i32>], max_new: usize,
                params: DecodeParams, pool: Option<&ThreadPool>)
                -> Result<Vec<Vec<i32>>> {
    let mut eng = DecodeEngine::new(model, params, pool);
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(GenRequest { id: i, prompt: p.clone(), max_new })?;
    }
    Ok(eng.run()?.into_iter().map(|r| r.generated).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::InferConfig;

    fn tiny_model() -> InferModel {
        let cfg = InferConfig { vocab_size: 64, d_model: 16, n_layers: 2,
                                n_heads: 2, d_ff: 24, rope_theta: 10000.0,
                                norm_ss: false, embproj: false };
        InferModel::synthetic(&cfg, 11)
    }

    #[test]
    fn generates_requested_token_counts() {
        let m = tiny_model();
        let prompts = vec![vec![1, 2, 3], vec![4], vec![5, 6]];
        let outs = generate(&m, &prompts, 5,
                            DecodeParams::greedy(16, 16, 2), None)
            .unwrap();
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert_eq!(o.len(), 5);
            for &t in o {
                assert!((0..64).contains(&t));
            }
        }
    }

    #[test]
    fn batch_composition_does_not_change_streams() {
        let m = tiny_model();
        let prompts = vec![vec![1, 2, 3, 4], vec![9], vec![7, 8, 9, 10, 11]];
        let solo: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| generate(&m, std::slice::from_ref(p), 6,
                              DecodeParams::greedy(4, 4, 1), None)
                 .unwrap()
                 .remove(0))
            .collect();
        for max_batch in [1usize, 2, 3] {
            let together = generate(&m, &prompts, 6,
                                    DecodeParams::greedy(4, 4, max_batch),
                                    None)
                .unwrap();
            assert_eq!(together, solo, "max_batch={max_batch}");
        }
    }

    #[test]
    fn prefill_chunk_does_not_change_streams() {
        let m = tiny_model();
        let prompts = vec![vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
                           vec![11, 12, 13], vec![5; 17]];
        let chunk1 = {
            let mut p = DecodeParams::greedy(4, 4, 3);
            p.prefill_chunk = 1;
            generate(&m, &prompts, 6, p, None).unwrap()
        };
        for chunk in [2usize, 7, 64] {
            let mut p = DecodeParams::greedy(4, 4, 3);
            p.prefill_chunk = chunk;
            let got = generate(&m, &prompts, 6, p, None).unwrap();
            assert_eq!(got, chunk1, "prefill_chunk={chunk}");
        }
    }

    #[test]
    fn scheduler_admits_and_evicts_at_step_granularity() {
        let m = tiny_model();
        let mut eng = DecodeEngine::new(&m, DecodeParams::greedy(16, 16, 2),
                                        None);
        for i in 0..4 {
            eng.submit(GenRequest { id: i, prompt: vec![1, 2], max_new: 2 })
                .unwrap();
        }
        assert_eq!(eng.n_pending(), 4);
        // First step admits only max_batch = 2 sequences.
        assert_eq!(eng.step().unwrap(), 2 * 2);
        let results = eng.run().unwrap();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.generated.len(), 2);
        }
        // All requests saw the same prompt => identical greedy streams.
        for r in &results[1..] {
            assert_eq!(r.generated, results[0].generated);
        }
        assert!(eng.stats.tokens_processed >= 4 * 3);
        assert_eq!(eng.stats.tokens_prefilled, 4 * 2);
        assert!(eng.stats.peak_kv_bytes > 0);
    }

    #[test]
    fn submit_rejects_out_of_vocab_without_killing_the_loop() {
        let m = tiny_model();
        let mut eng = DecodeEngine::new(&m, DecodeParams::greedy(16, 16, 2),
                                        None);
        eng.submit(GenRequest { id: 0, prompt: vec![1, 2], max_new: 2 })
            .unwrap();
        // Bad request is rejected up front...
        assert!(eng
            .submit(GenRequest { id: 1, prompt: vec![1, 64], max_new: 2 })
            .is_err());
        assert!(eng
            .submit(GenRequest { id: 2, prompt: vec![-3], max_new: 2 })
            .is_err());
        // ...and the loop still serves the good one.
        let results = eng.run().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 0);
        assert_eq!(results[0].generated.len(), 2);
    }

    #[test]
    fn cancel_frees_slots_queued_and_active() {
        let m = tiny_model();
        let mut eng = DecodeEngine::new(&m, DecodeParams::greedy(16, 16, 2),
                                        None);
        for i in 0..4 {
            eng.submit(GenRequest { id: i, prompt: vec![1, 2], max_new: 4 })
                .unwrap();
        }
        eng.step().unwrap();
        assert_eq!((eng.n_active(), eng.n_queued()), (2, 2));
        // Cancel one active and one still-queued request.
        assert!(eng.cancel(0));
        assert!(eng.cancel(3));
        assert!(!eng.cancel(0), "double-cancel is a no-op");
        assert!(!eng.cancel(99), "unknown id is a no-op");
        assert_eq!((eng.n_active(), eng.n_queued()), (1, 1));
        let results = eng.run().unwrap();
        let ids: Vec<usize> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(eng.stats.cancelled, 2);
        assert_eq!((eng.n_active(), eng.n_queued()), (0, 0));
    }

    #[test]
    fn emitted_tokens_stream_the_finished_results() {
        let m = tiny_model();
        let mut eng = DecodeEngine::new(&m, DecodeParams::greedy(16, 16, 2),
                                        None);
        for i in 0..2 {
            eng.submit(GenRequest { id: i, prompt: vec![1, 2 + i as i32],
                                    max_new: 3 })
                .unwrap();
        }
        let mut streams = vec![Vec::new(), Vec::new()];
        while eng.n_pending() > 0 {
            eng.step().unwrap();
            for (id, tok) in eng.take_emitted() {
                streams[id].push(tok);
            }
        }
        let mut fin = eng.take_finished();
        fin.sort_by_key(|r| r.id);
        assert_eq!(fin.len(), 2);
        for (r, s) in fin.iter().zip(&streams) {
            assert_eq!(&r.generated, s,
                       "per-step emission must equal the final stream");
        }
    }

    #[test]
    fn max_new_zero_generates_nothing() {
        let m = tiny_model();
        let outs = generate(&m, &[vec![1, 2, 3], vec![4]], 0,
                            DecodeParams::greedy(4, 4, 2), None)
            .unwrap();
        assert_eq!(outs, vec![Vec::<i32>::new(), Vec::new()]);
    }

    #[test]
    fn empty_prompt_gets_bos() {
        let m = tiny_model();
        let outs = generate(&m, &[vec![]], 3,
                            DecodeParams::greedy(16, 16, 1), None)
            .unwrap();
        assert_eq!(outs[0].len(), 3);
    }

    #[test]
    fn kv_pool_balances_after_run_and_drop() {
        let m = tiny_model();
        let mut eng = DecodeEngine::new(&m, DecodeParams::greedy(4, 4, 2),
                                        None);
        for i in 0..3 {
            eng.submit(GenRequest { id: i, prompt: vec![1, 2, 3],
                                    max_new: 4 })
                .unwrap();
        }
        eng.step().unwrap();
        assert!(eng.pool_gauges().pages_live > 0,
                "active sequences hold pages");
        // Cancel one active sequence mid-decode, finish the rest.
        assert!(eng.cancel(0));
        eng.run().unwrap();
        let g = eng.pool_gauges();
        assert_eq!((g.refs_live, g.pages_live), (0, 0),
                   "every teardown path returns its pages");
        assert!(g.pages_peak > 0, "peak gauge saw the live pages");
        // Drop re-checks balance via its debug_assert.
    }

    #[test]
    fn pool_budget_bounds_submission() {
        let m = tiny_model();
        let mut p = DecodeParams::greedy(4, 4, 2);
        p.kv_page_rows = 4;
        p.kv_pool_mb = 1;
        let mut eng = DecodeEngine::new(&m, p, None);
        let cap = eng.pool_gauges().cap_pages;
        assert!(cap > 0, "1 MiB budget maps to a positive page cap");
        // A request whose worst case can never fit is rejected at
        // submit time...
        assert!(eng
            .submit(GenRequest { id: 0, prompt: vec![1],
                                 max_new: 1_000_000 })
            .is_err());
        // ...while a sane one runs to completion under the budget.
        eng.submit(GenRequest { id: 1, prompt: vec![1, 2], max_new: 3 })
            .unwrap();
        let results = eng.run().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].generated.len(), 3);
    }

    #[test]
    fn prefix_sharing_stores_common_pages_once() {
        let m = tiny_model();
        // nh = 2, page_rows = 4 => 2 tokens per page. A 9-token prompt
        // shares its first 8 tokens (whole pages below the last prompt
        // token). max_batch = 1 keeps admissions serial so request 1
        // is admitted after request 0 registered its prefix.
        let prompt: Vec<i32> = (1..=9).collect();
        let run = |share: bool| {
            let mut p = DecodeParams::greedy(4, 4, 1);
            p.kv_page_rows = 4;
            p.share_prefix = share;
            let mut eng = DecodeEngine::new(&m, p, None);
            for id in 0..2 {
                eng.submit(GenRequest { id, prompt: prompt.clone(),
                                        max_new: 4 })
                    .unwrap();
            }
            let results = eng.run().unwrap();
            let shared = eng.stats.kv_pages_shared;
            let streams: Vec<Vec<i32>> =
                results.into_iter().map(|r| r.generated).collect();
            (streams, shared)
        };
        let (unshared, s0) = run(false);
        let (shared, s1) = run(true);
        assert_eq!(shared, unshared,
                   "shared-prefix streams are bit-identical");
        assert_eq!(s0, 0, "sharing off never aliases pages");
        assert!(s1 > 0, "request 1 adopted request 0's prefix pages");
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let m = tiny_model();
        let p = DecodeParams { temperature: 0.8, seed: 42,
                               ..DecodeParams::greedy(16, 16, 2) };
        let a = generate(&m, &[vec![1, 2], vec![3]], 4, p, None).unwrap();
        let b = generate(&m, &[vec![1, 2], vec![3]], 4, p, None).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn truncated_sampling_is_seed_deterministic_and_k1_is_greedy() {
        let m = tiny_model();
        let prompts = vec![vec![1, 2, 3], vec![4, 5]];
        let p = DecodeParams { temperature: 0.9, top_k: 4, top_p: 0.9,
                               seed: 7, ..DecodeParams::greedy(4, 4, 2) };
        let a = generate(&m, &prompts, 5, p, None).unwrap();
        let b = generate(&m, &prompts, 5, p, None).unwrap();
        assert_eq!(a, b);
        // top_k = 1 collapses to the greedy stream at any temperature.
        let k1 = DecodeParams { temperature: 0.9, top_k: 1, seed: 7,
                                ..DecodeParams::greedy(4, 4, 2) };
        let greedy = DecodeParams::greedy(4, 4, 2);
        assert_eq!(generate(&m, &prompts, 5, k1, None).unwrap(),
                   generate(&m, &prompts, 5, greedy, None).unwrap());
    }
}
