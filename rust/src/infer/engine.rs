//! Continuous-batching decode scheduler (DESIGN.md §8).
//!
//! [`DecodeEngine`] owns a FIFO of [`GenRequest`]s and a set of active
//! sequences capped at `max_batch`. Every [`DecodeEngine::step`]
//! processes exactly one token per active sequence — prompt tokens
//! (prefill) and generated tokens ride the same batched forward pass —
//! then evicts finished sequences and admits queued ones, so the batch
//! stays full at *step* granularity.
//!
//! Determinism: a sequence's stream depends only on (model, its own
//! prompt, decode params, its own sampling RNG) — per-row kernels and
//! per-sequence attention make results independent of batch composition
//! and worker count, so continuous batching never changes output
//! (pinned by `rust/tests/infer_properties.rs`).

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;

use super::kv::SeqKv;
use super::{sample_token, InferModel};

/// Runtime decode configuration.
#[derive(Clone, Copy, Debug)]
pub struct DecodeParams {
    /// Activation fake-quant bits (16 = off), like the evalq input.
    pub a_bits: u32,
    /// KV-cache storage bits (16 = f32 passthrough).
    pub kv_bits: u32,
    /// Active-sequence cap (the batching knob).
    pub max_batch: usize,
    /// <= 0 is greedy argmax.
    pub temperature: f32,
    /// Base seed; each request samples from `seed ^ request id`.
    pub seed: u64,
}

impl DecodeParams {
    pub fn greedy(a_bits: u32, kv_bits: u32, max_batch: usize)
                  -> DecodeParams {
        DecodeParams { a_bits, kv_bits, max_batch, temperature: 0.0,
                       seed: 0 }
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: usize,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// A finished request: the prompt plus `generated` new tokens.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: usize,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
}

struct Active {
    id: usize,
    /// Prompt followed by generated tokens.
    tokens: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    cache: SeqKv,
    rng: Pcg,
}

impl Active {
    fn n_generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    fn done(&self) -> bool {
        self.n_generated() >= self.max_new
    }
}

/// Totals of one engine run (the serve-bench numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    /// Forward tokens processed (prefill + decode positions).
    pub tokens_processed: u64,
    /// Newly generated tokens.
    pub tokens_generated: u64,
    pub steps: u64,
    pub wall_secs: f64,
    /// Peak total KV bytes across concurrently-active sequences.
    pub peak_kv_bytes: usize,
}

impl DecodeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_processed as f64 / self.wall_secs.max(1e-9)
    }

    pub fn generated_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_secs.max(1e-9)
    }
}

pub struct DecodeEngine<'m, 'p> {
    model: &'m InferModel,
    params: DecodeParams,
    pool: Option<&'p ThreadPool>,
    queue: VecDeque<GenRequest>,
    active: Vec<Active>,
    finished: Vec<GenResult>,
    pub stats: DecodeStats,
}

impl<'m, 'p> DecodeEngine<'m, 'p> {
    pub fn new(model: &'m InferModel, params: DecodeParams,
               pool: Option<&'p ThreadPool>) -> DecodeEngine<'m, 'p> {
        assert!(params.max_batch > 0, "max_batch must be positive");
        DecodeEngine { model, params, pool, queue: VecDeque::new(),
                       active: Vec::new(), finished: Vec::new(),
                       stats: DecodeStats::default() }
    }

    /// Enqueue a request (admitted at the next step with a free slot).
    /// Empty prompts are given a BOS-like token 0 so position 0 exists.
    pub fn submit(&mut self, mut req: GenRequest) {
        if req.prompt.is_empty() {
            req.prompt.push(0);
        }
        self.queue.push_back(req);
    }

    pub fn n_pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    fn admit(&mut self) {
        while self.active.len() < self.params.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            self.active.push(Active {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: req.prompt,
                max_new: req.max_new,
                cache: self.model.new_cache(self.params.kv_bits),
                rng: Pcg::new(self.params.seed ^ req.id as u64, 77),
            });
        }
    }

    /// One engine step: admit, run one batched forward token per active
    /// sequence, sample where the prompt is exhausted, evict finished
    /// sequences. Returns the number of tokens processed (0 = idle).
    pub fn step(&mut self) -> usize {
        let t0 = Instant::now();
        self.admit();
        if self.active.is_empty() {
            return 0;
        }
        // Each sequence feeds the token at its cache position; logits
        // from the last known token produce the next sample. A sequence
        // samples only while it still owes tokens (`max_new` 0 must
        // generate nothing), and the logits head is skipped entirely on
        // pure-prefill steps where nobody will.
        let tokens: Vec<i32> = self
            .active
            .iter()
            .map(|a| a.tokens[a.cache.n_tokens()])
            .collect();
        let will_sample = |a: &Active| {
            a.cache.n_tokens() + 1 == a.tokens.len()
                && a.n_generated() < a.max_new
        };
        let want_logits = self.active.iter().any(|a| will_sample(a));
        let logits = {
            let mut caches: Vec<&mut SeqKv> =
                self.active.iter_mut().map(|a| &mut a.cache).collect();
            self.model.decode_step(self.pool, &tokens, &mut caches,
                                   self.params.a_bits, want_logits)
        };
        if let Some(logits) = logits {
            let vocab = self.model.cfg.vocab_size;
            for (r, a) in self.active.iter_mut().enumerate() {
                // After the forward, the cache advanced past the fed
                // token.
                if a.cache.n_tokens() == a.tokens.len()
                    && a.n_generated() < a.max_new
                {
                    let row = &logits.data()[r * vocab..(r + 1) * vocab];
                    let next = sample_token(row, self.params.temperature,
                                            &mut a.rng);
                    a.tokens.push(next);
                }
            }
        }
        let kv_bytes: usize =
            self.active.iter().map(|a| a.cache.bytes()).sum();
        self.stats.peak_kv_bytes = self.stats.peak_kv_bytes.max(kv_bytes);
        let processed = tokens.len();
        self.stats.tokens_processed += processed as u64;
        self.stats.steps += 1;
        // Evict in place, keeping submission order within `finished`
        // resolution by id later.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done() {
                let a = self.active.remove(i);
                self.stats.tokens_generated += a.n_generated() as u64;
                self.finished.push(GenResult {
                    id: a.id,
                    prompt_len: a.prompt_len,
                    generated: a.tokens[a.prompt_len..].to_vec(),
                });
            } else {
                i += 1;
            }
        }
        self.stats.wall_secs += t0.elapsed().as_secs_f64();
        processed
    }

    /// Drive until every submitted request finishes; results sorted by
    /// request id.
    pub fn run(&mut self) -> Vec<GenResult> {
        while self.n_pending() > 0 {
            self.step();
        }
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Decode `prompts` to completion under `params`; returns the generated
/// tokens per prompt (order matches input). The one-call entry point the
/// consistency checks and `osp generate` use.
pub fn generate(model: &InferModel, prompts: &[Vec<i32>], max_new: usize,
                params: DecodeParams, pool: Option<&ThreadPool>)
                -> Vec<Vec<i32>> {
    let mut eng = DecodeEngine::new(model, params, pool);
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(GenRequest { id: i, prompt: p.clone(), max_new });
    }
    eng.run().into_iter().map(|r| r.generated).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::InferConfig;

    fn tiny_model() -> InferModel {
        let cfg = InferConfig { vocab_size: 64, d_model: 16, n_layers: 2,
                                n_heads: 2, d_ff: 24, rope_theta: 10000.0,
                                norm_ss: false, embproj: false };
        InferModel::synthetic(&cfg, 11)
    }

    #[test]
    fn generates_requested_token_counts() {
        let m = tiny_model();
        let prompts = vec![vec![1, 2, 3], vec![4], vec![5, 6]];
        let outs = generate(&m, &prompts, 5,
                            DecodeParams::greedy(16, 16, 2), None);
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert_eq!(o.len(), 5);
            for &t in o {
                assert!((0..64).contains(&t));
            }
        }
    }

    #[test]
    fn batch_composition_does_not_change_streams() {
        let m = tiny_model();
        let prompts = vec![vec![1, 2, 3, 4], vec![9], vec![7, 8, 9, 10, 11]];
        let solo: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| generate(&m, std::slice::from_ref(p), 6,
                              DecodeParams::greedy(4, 4, 1), None)
                 .remove(0))
            .collect();
        for max_batch in [1usize, 2, 3] {
            let together = generate(&m, &prompts, 6,
                                    DecodeParams::greedy(4, 4, max_batch),
                                    None);
            assert_eq!(together, solo, "max_batch={max_batch}");
        }
    }

    #[test]
    fn scheduler_admits_and_evicts_at_step_granularity() {
        let m = tiny_model();
        let mut eng = DecodeEngine::new(&m, DecodeParams::greedy(16, 16, 2),
                                        None);
        for i in 0..4 {
            eng.submit(GenRequest { id: i, prompt: vec![1, 2], max_new: 2 });
        }
        assert_eq!(eng.n_pending(), 4);
        // First step admits only max_batch = 2 sequences.
        assert_eq!(eng.step(), 2);
        let results = eng.run();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i);
            assert_eq!(r.generated.len(), 2);
        }
        // All requests saw the same prompt => identical greedy streams.
        for r in &results[1..] {
            assert_eq!(r.generated, results[0].generated);
        }
        assert!(eng.stats.tokens_processed >= 4 * 3);
        assert!(eng.stats.peak_kv_bytes > 0);
    }

    #[test]
    fn max_new_zero_generates_nothing() {
        let m = tiny_model();
        let outs = generate(&m, &[vec![1, 2, 3], vec![4]], 0,
                            DecodeParams::greedy(4, 4, 2), None);
        assert_eq!(outs, vec![Vec::<i32>::new(), Vec::new()]);
    }

    #[test]
    fn empty_prompt_gets_bos() {
        let m = tiny_model();
        let outs = generate(&m, &[vec![]], 3,
                            DecodeParams::greedy(16, 16, 1), None);
        assert_eq!(outs[0].len(), 3);
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let m = tiny_model();
        let p = DecodeParams { a_bits: 16, kv_bits: 16, max_batch: 2,
                               temperature: 0.8, seed: 42 };
        let a = generate(&m, &[vec![1, 2], vec![3]], 4, p, None);
        let b = generate(&m, &[vec![1, 2], vec![3]], 4, p, None);
        assert_eq!(a, b);
    }
}
