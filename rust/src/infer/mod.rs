//! Host-side autoregressive decode engine (DESIGN.md §8): batched
//! greedy/temperature generation **directly on packed [`QTensor`]
//! weights** via the fused `qmatmul_rhs` kernels — no dense
//! dequantization — with a per-sequence quantized KV cache ([`kv`]) and
//! a continuous-batching scheduler ([`engine`]).
//!
//! The forward pass mirrors the evalq graph semantics
//! (`python/compile/model.py`): RMSNorm/SSNorm, RoPE on q/k, per-token
//! RTN fake-quantization of every linear input activation (`a_bits`),
//! KV-cache quantization after RoPE (`kv_bits`), and the optional online
//! Hadamard on the FFN hidden state (`had_flag`, paired with the
//! pre-rotated `w_down` the PTQ pipeline emits). Bit-widths follow the
//! same `levels = 2^(bits-1) - 1` mapping as the executables.
//!
//! Parity contract (pinned by `rust/tests/infer_properties.rs`):
//!
//! * Decoding on packed weights is bit-identical to decoding on their
//!   [`QTensor::dequantize`]d f32 twins — the fused kernels share the
//!   dense kernels' accumulation order, and the packed KV cache stores
//!   exactly the fake-quantized values the dense cache holds.
//! * Serial and pool-parallel decode are bit-identical for any worker
//!   count: batch rows, column stripes, and per-sequence attention jobs
//!   each compute with the same per-element arithmetic.
//! * A sequence's token stream is independent of batch composition, so
//!   the continuous-batching scheduler never changes results.

pub mod engine;
pub mod kv;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::levels_for_bits;
use crate::quant::QParam;
use crate::tensor::linalg;
use crate::tensor::qtensor::QTensor;
use crate::tensor::{par, Tensor};
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;

use kv::SeqKv;

pub use engine::{DecodeEngine, DecodeParams, GenRequest, GenResult};

/// The decoder shape the engine runs (subset of the lowering-time model
/// config, plus the norm/embproj knobs the arch name encodes).
#[derive(Clone, Debug)]
pub struct InferConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub rope_theta: f32,
    /// Single-Scale RMSNorm (scalar gamma) vs per-channel RMSNorm.
    pub norm_ss: bool,
    pub embproj: bool,
}

impl InferConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Decode the norm/embproj knobs from an arch tag
    /// (`{rms|ss}norm_{plain|embproj}`).
    pub fn arch_knobs(arch: &str) -> Result<(bool, bool)> {
        let norm_ss = match arch.split("norm_").next() {
            Some("rms") => false,
            Some("ss") => true,
            _ => bail!("unknown arch '{arch}' (want {{rms|ss}}norm_...)"),
        };
        let embproj = match arch.split("norm_").nth(1) {
            Some("plain") => false,
            Some("embproj") => true,
            _ => bail!("unknown arch '{arch}' (want ..._{{plain|embproj}})"),
        };
        Ok((norm_ss, embproj))
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("n_heads {} must divide d_model {}", self.n_heads,
                  self.d_model);
        }
        if self.head_dim() % 2 != 0 {
            bail!("head_dim {} must be even (RoPE pairs channels)",
                  self.head_dim());
        }
        Ok(())
    }
}

/// One weight matrix of the decode model: packed codes (the deployment
/// path) or a dense f32 fallback. All kernels are bit-identical across
/// the two representations of the same dequantized values.
pub enum Linear {
    Dense(Tensor),
    Packed(QTensor),
}

impl Linear {
    fn shape(&self) -> &[usize] {
        match self {
            Linear::Dense(t) => t.shape(),
            Linear::Packed(q) => q.shape(),
        }
    }

    /// C = A @ deq(self); `self` is `[in, out]`, A is `[batch, in]`.
    fn matmul(&self, pool: Option<&ThreadPool>, a: &Tensor) -> Tensor {
        match self {
            Linear::Dense(t) => par::matmul_with(pool, a, t),
            Linear::Packed(q) => q.qmatmul_rhs_with(pool, a),
        }
    }

    /// Row `i` dequantized into `out` (the embedding lookup).
    fn row_into(&self, i: usize, out: &mut [f32]) {
        match self {
            Linear::Dense(t) => out.copy_from_slice(t.row(i)),
            Linear::Packed(q) => q.dequant_row_into(i, out),
        }
    }

    /// Serialized weight bytes in this representation.
    pub fn packed_bytes(&self) -> usize {
        match self {
            Linear::Dense(t) => 4 * t.len(),
            Linear::Packed(q) => q.packed_bytes(),
        }
    }

    fn dequantized(&self) -> Linear {
        match self {
            Linear::Dense(t) => Linear::Dense(t.clone()),
            Linear::Packed(q) => Linear::Dense(q.dequantize()),
        }
    }

    fn quantized(&self, bits: u32) -> Linear {
        match self {
            Linear::Dense(t) if bits < 16 => {
                Linear::Packed(crate::quant::rtn::quantize_per_channel_q(
                    t, bits))
            }
            Linear::Dense(t) => Linear::Dense(t.clone()),
            Linear::Packed(q) => Linear::Packed(q.clone()),
        }
    }
}

struct LayerWeights {
    attn_norm: Tensor,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    ffn_norm: Tensor,
    w_gate: Linear,
    w_up: Linear,
    w_down: Linear,
}

/// A decode-ready model: the packed leaves of a
/// [`crate::quant::QuantizedModel`] (or dense f32 weights) arranged for
/// the per-token forward pass.
pub struct InferModel {
    pub cfg: InferConfig,
    /// Online FFN Hadamard (must match the weight preparation).
    pub had_flag: bool,
    embed: Linear,
    embproj_in: Option<Linear>,
    embproj_out: Option<Linear>,
    layers: Vec<LayerWeights>,
    final_norm: Tensor,
    unembed: Linear,
    /// Precomputed RoPE frequencies `theta^(-j/half)`, one per
    /// channel pair — keeps `powf` out of the per-token hot loop.
    rope_inv_freq: Vec<f32>,
}

fn rope_inv_freq(cfg: &InferConfig) -> Vec<f32> {
    let half = cfg.head_dim() / 2;
    (0..half)
        .map(|j| cfg.rope_theta.powf(-(j as f32) / half as f32))
        .collect()
}

fn norm_leaf(p: &QParam) -> Tensor {
    match p {
        QParam::Dense(t) => t.clone(),
        QParam::Packed(q) => q.dequantize(),
    }
}

fn linear_leaf(p: &QParam) -> Linear {
    match p {
        QParam::Dense(t) => Linear::Dense(t.clone()),
        QParam::Packed(q) => Linear::Packed(q.clone()),
    }
}

impl InferModel {
    /// Build from quantized-model leaves in manifest parameter order
    /// (embed, [embproj_in, embproj_out], per layer {attn_norm, wq, wk,
    /// wv, wo, ffn_norm, w_gate, w_up, w_down}, final_norm, unembed).
    /// `n_heads` and `rope_theta` come from the lowering-time config —
    /// they are not recoverable from the leaf shapes.
    pub fn from_qparams(arch: &str, params: &[QParam], n_heads: usize,
                        rope_theta: f32, had_flag: bool)
                        -> Result<InferModel> {
        let (norm_ss, embproj) = InferConfig::arch_knobs(arch)?;
        let head = 1 + if embproj { 2 } else { 0 };
        let tail = 2; // final_norm, unembed
        let body = params
            .len()
            .checked_sub(head + tail)
            .ok_or_else(|| anyhow!("{} leaves is too few for '{arch}'",
                                   params.len()))?;
        if body % 9 != 0 {
            bail!("{} leaves does not match '{arch}' (9 per layer)",
                  params.len());
        }
        let n_layers = body / 9;
        if n_layers == 0 {
            bail!("'{arch}' model with zero layers");
        }
        let embed = linear_leaf(&params[0]);
        if embed.shape().len() != 2 {
            bail!("embed leaf is not 2-D");
        }
        let (vocab_size, d_model) = (embed.shape()[0], embed.shape()[1]);
        let (embproj_in, embproj_out) = if embproj {
            (Some(linear_leaf(&params[1])), Some(linear_leaf(&params[2])))
        } else {
            (None, None)
        };
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let b = head + li * 9;
            layers.push(LayerWeights {
                attn_norm: norm_leaf(&params[b]),
                wq: linear_leaf(&params[b + 1]),
                wk: linear_leaf(&params[b + 2]),
                wv: linear_leaf(&params[b + 3]),
                wo: linear_leaf(&params[b + 4]),
                ffn_norm: norm_leaf(&params[b + 5]),
                w_gate: linear_leaf(&params[b + 6]),
                w_up: linear_leaf(&params[b + 7]),
                w_down: linear_leaf(&params[b + 8]),
            });
        }
        let d_ff = layers[0].w_gate.shape()[1];
        let final_norm = norm_leaf(&params[head + body]);
        let unembed = linear_leaf(&params[head + body + 1]);
        if unembed.shape() != &[d_model, vocab_size] {
            bail!("unembed shape {:?} != [{d_model}, {vocab_size}]",
                  unembed.shape());
        }
        let want_norm = if norm_ss { 1 } else { d_model };
        for (what, len) in [("attn_norm", layers[0].attn_norm.len()),
                            ("ffn_norm", layers[0].ffn_norm.len()),
                            ("final_norm", final_norm.len())] {
            if len != want_norm {
                bail!("{what} has {len} scales, '{arch}' wants \
                       {want_norm}");
            }
        }
        let cfg = InferConfig { vocab_size, d_model, n_layers, n_heads,
                                d_ff, rope_theta, norm_ss, embproj };
        cfg.validate()?;
        let rope_inv_freq = rope_inv_freq(&cfg);
        Ok(InferModel { cfg, had_flag, embed, embproj_in, embproj_out,
                        layers, final_norm, unembed, rope_inv_freq })
    }

    /// Wrap dense f32 checkpoint leaves (same ordering) — the unquantized
    /// baseline the consistency checks decode against.
    pub fn from_dense_params(arch: &str, params: &[Tensor], n_heads: usize,
                             rope_theta: f32) -> Result<InferModel> {
        let qp: Vec<QParam> =
            params.iter().cloned().map(QParam::Dense).collect();
        InferModel::from_qparams(arch, &qp, n_heads, rope_theta, false)
    }

    /// The dense-f32 twin: every packed leaf dequantized, everything
    /// else cloned. Same token streams bit-for-bit (the parity
    /// contract); used by `osp generate --check` and the property tests.
    pub fn dequantized(&self) -> InferModel {
        InferModel {
            cfg: self.cfg.clone(),
            had_flag: self.had_flag,
            embed: self.embed.dequantized(),
            embproj_in: self.embproj_in.as_ref().map(|l| l.dequantized()),
            embproj_out: self.embproj_out.as_ref().map(|l| l.dequantized()),
            layers: self
                .layers
                .iter()
                .map(|l| LayerWeights {
                    attn_norm: l.attn_norm.clone(),
                    wq: l.wq.dequantized(),
                    wk: l.wk.dequantized(),
                    wv: l.wv.dequantized(),
                    wo: l.wo.dequantized(),
                    ffn_norm: l.ffn_norm.clone(),
                    w_gate: l.w_gate.dequantized(),
                    w_up: l.w_up.dequantized(),
                    w_down: l.w_down.dequantized(),
                })
                .collect(),
            final_norm: self.final_norm.clone(),
            unembed: self.unembed.dequantized(),
            rope_inv_freq: self.rope_inv_freq.clone(),
        }
    }

    /// RTN-quantize every matrix leaf to `w_bits` packed codes (norm
    /// leaves stay dense) — the synthetic-model path serve-bench and the
    /// property tests use; real checkpoints go through `quant::prepare`.
    pub fn quantized(&self, w_bits: u32) -> InferModel {
        InferModel {
            cfg: self.cfg.clone(),
            had_flag: self.had_flag,
            embed: self.embed.quantized(w_bits),
            embproj_in: self.embproj_in.as_ref()
                .map(|l| l.quantized(w_bits)),
            embproj_out: self.embproj_out.as_ref()
                .map(|l| l.quantized(w_bits)),
            layers: self
                .layers
                .iter()
                .map(|l| LayerWeights {
                    attn_norm: l.attn_norm.clone(),
                    wq: l.wq.quantized(w_bits),
                    wk: l.wk.quantized(w_bits),
                    wv: l.wv.quantized(w_bits),
                    wo: l.wo.quantized(w_bits),
                    ffn_norm: l.ffn_norm.clone(),
                    w_gate: l.w_gate.quantized(w_bits),
                    w_up: l.w_up.quantized(w_bits),
                    w_down: l.w_down.quantized(w_bits),
                })
                .collect(),
            final_norm: self.final_norm.clone(),
            unembed: self.unembed.quantized(w_bits),
            rope_inv_freq: self.rope_inv_freq.clone(),
        }
    }

    /// A random dense model at `cfg` (normal init, residual-branch
    /// scaling like the init artifact) — the no-artifacts path for
    /// serve-bench, the examples, and the property tests.
    pub fn synthetic(cfg: &InferConfig, seed: u64) -> InferModel {
        cfg.validate().expect("synthetic: invalid InferConfig");
        let mut rng = Pcg::new(seed, 23);
        let std = 0.05f32;
        let res = std / (2.0 * cfg.n_layers as f32).sqrt();
        let mut randn = |shape: &[usize], s: f32| -> Linear {
            let mut t = Tensor::zeros(shape);
            rng.fill_normal(t.data_mut(), s);
            Linear::Dense(t)
        };
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let norm = |ss: bool| -> Tensor {
            if ss {
                Tensor::full(&[1], (d as f32).sqrt())
            } else {
                Tensor::full(&[d], 1.0)
            }
        };
        let embed = randn(&[v, d], std);
        let (embproj_in, embproj_out) = if cfg.embproj {
            (Some(randn(&[d, d], 1.0 / (d as f32).sqrt())),
             Some(randn(&[d, d], 1.0 / (d as f32).sqrt())))
        } else {
            (None, None)
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                attn_norm: norm(cfg.norm_ss),
                wq: randn(&[d, d], std),
                wk: randn(&[d, d], std),
                wv: randn(&[d, d], std),
                wo: randn(&[d, d], res),
                ffn_norm: norm(cfg.norm_ss),
                w_gate: randn(&[d, f], std),
                w_up: randn(&[d, f], std),
                w_down: randn(&[f, d], res),
            })
            .collect();
        let final_norm = norm(cfg.norm_ss);
        let unembed = randn(&[d, v], std);
        InferModel { cfg: cfg.clone(), had_flag: false, embed, embproj_in,
                     embproj_out, layers, final_norm, unembed,
                     rope_inv_freq: rope_inv_freq(cfg) }
    }

    /// Serialized weight bytes in the current representation.
    pub fn weight_bytes(&self) -> usize {
        let mut b = self.embed.packed_bytes() + self.unembed.packed_bytes();
        for l in [&self.embproj_in, &self.embproj_out].into_iter().flatten() {
            b += l.packed_bytes();
        }
        for l in &self.layers {
            b += 4 * (l.attn_norm.len() + l.ffn_norm.len())
                + l.wq.packed_bytes() + l.wk.packed_bytes()
                + l.wv.packed_bytes() + l.wo.packed_bytes()
                + l.w_gate.packed_bytes() + l.w_up.packed_bytes()
                + l.w_down.packed_bytes();
        }
        b + 4 * self.final_norm.len()
    }

    /// Fresh per-sequence KV cache for this model.
    pub fn new_cache(&self, kv_bits: u32) -> SeqKv {
        SeqKv::new(self.cfg.n_layers, self.cfg.n_heads,
                   self.cfg.head_dim(), kv_bits)
    }

    /// One decode step for a batch of sequences: feed `tokens[r]` at
    /// position `caches[r].n_tokens()` and return next-token logits
    /// `[batch, vocab]`. Linear layers run batched across sequences (the
    /// decode-amortization win); attention runs per sequence over its
    /// quantized cache, one pool job each.
    pub fn forward_step(&self, pool: Option<&ThreadPool>, tokens: &[i32],
                        caches: &mut [SeqKv], a_bits: u32) -> Tensor {
        let mut refs: Vec<&mut SeqKv> = caches.iter_mut().collect();
        self.forward_step_refs(pool, tokens, &mut refs, a_bits)
    }

    /// [`InferModel::forward_step`] over a scattered view of caches (the
    /// scheduler's sequences own theirs individually).
    pub fn forward_step_refs(&self, pool: Option<&ThreadPool>,
                             tokens: &[i32], caches: &mut [&mut SeqKv],
                             a_bits: u32) -> Tensor {
        self.decode_step(pool, tokens, caches, a_bits, true)
            .expect("want_logits")
    }

    /// The scheduler's entry point: like [`InferModel::forward_step_refs`]
    /// but with `want_logits = false` the final-norm/EmbProj/unembed head
    /// — the model's largest matmul — is skipped and `None` returned.
    /// Only valid for steps where no sequence samples (pure prefill);
    /// the trunk and every cache update are identical either way.
    pub fn decode_step(&self, pool: Option<&ThreadPool>, tokens: &[i32],
                       caches: &mut [&mut SeqKv], a_bits: u32,
                       want_logits: bool) -> Option<Tensor> {
        let bsz = tokens.len();
        assert_eq!(bsz, caches.len(), "one cache per sequence");
        assert!(bsz > 0, "empty decode batch");
        let d = self.cfg.d_model;
        let a_levels = levels_for_bits(a_bits);

        // Embedding lookup (+ EmbProj input projection).
        let mut x = Tensor::zeros(&[bsz, d]);
        for (r, &t) in tokens.iter().enumerate() {
            assert!((t as usize) < self.cfg.vocab_size,
                    "token {t} out of vocab");
            self.embed.row_into(t as usize, x.row_mut(r));
        }
        if let Some(p_in) = &self.embproj_in {
            x = p_in.matmul(pool, &x);
        }

        for (li, lw) in self.layers.iter().enumerate() {
            // ---- MHSA ----
            let mut h = x.clone();
            for row in h.data_mut().chunks_mut(d) {
                norm_row(row, &lw.attn_norm, self.cfg.norm_ss);
                fake_quant_row(row, a_levels);
            }
            let q = lw.wq.matmul(pool, &h);
            let k = lw.wk.matmul(pool, &h);
            let v = lw.wv.matmul(pool, &h);
            let mut attn_out = Tensor::zeros(&[bsz, d]);
            {
                let (qd, kd, vd) = (q.data(), k.data(), v.data());
                let mut jobs: Vec<(&mut SeqKv, &mut [f32])> = caches
                    .iter_mut()
                    .map(|c| &mut **c)
                    .zip(attn_out.data_mut().chunks_mut(d))
                    .collect();
                par::par_map_mut(pool, &mut jobs, |r, (cache, out)| {
                    self.attend_one(li, &qd[r * d..(r + 1) * d],
                                    &kd[r * d..(r + 1) * d],
                                    &vd[r * d..(r + 1) * d], cache, out);
                });
            }
            for row in attn_out.data_mut().chunks_mut(d) {
                fake_quant_row(row, a_levels);
            }
            x = x.add(&lw.wo.matmul(pool, &attn_out));

            // ---- FFN (SwiGLU) ----
            let mut h = x.clone();
            for row in h.data_mut().chunks_mut(d) {
                norm_row(row, &lw.ffn_norm, self.cfg.norm_ss);
                fake_quant_row(row, a_levels);
            }
            let gate = lw.w_gate.matmul(pool, &h);
            let mut g = lw.w_up.matmul(pool, &h);
            for (gv, xv) in g.data_mut().iter_mut().zip(gate.data()) {
                *gv *= silu(*xv);
            }
            let f = self.cfg.d_ff;
            let (blk, hscale) = (linalg::pow2_block(f),
                                 1.0 / (linalg::pow2_block(f) as f32).sqrt());
            for row in g.data_mut().chunks_mut(f) {
                if self.had_flag {
                    linalg::hadamard_row(row, blk, hscale);
                }
                fake_quant_row(row, a_levels);
            }
            x = x.add(&lw.w_down.matmul(pool, &g));
        }

        // Advance every cache once per decoded token.
        for cache in caches.iter_mut() {
            cache.advance();
        }
        if !want_logits {
            return None;
        }

        let mut h = x;
        for row in h.data_mut().chunks_mut(d) {
            norm_row(row, &self.final_norm, self.cfg.norm_ss);
        }
        if let Some(p_out) = &self.embproj_out {
            h = p_out.matmul(pool, &h);
        }
        for row in h.data_mut().chunks_mut(d) {
            fake_quant_row(row, a_levels);
        }
        Some(self.unembed.matmul(pool, &h))
    }

    /// Per-sequence attention at layer `li`: RoPE q/k at the sequence's
    /// position, quantize-and-append k/v to the cache, then causal
    /// softmax attention over the cached rows into `out` (`[d_model]`,
    /// heads merged).
    fn attend_one(&self, li: usize, qrow: &[f32], krow: &[f32],
                  vrow: &[f32], cache: &mut SeqKv, out: &mut [f32]) {
        let (nh, hd) = (self.cfg.n_heads, self.cfg.head_dim());
        let pos = cache.n_tokens();
        let shd = (hd as f32).sqrt();
        // One scratch set per call (not per head): this runs per
        // sequence per layer per token, so allocations are hoisted out
        // of the head loop.
        let mut weights = vec![0.0f32; pos + 1];
        let mut qh = vec![0.0f32; hd];
        let mut kh = vec![0.0f32; hd];
        for h in 0..nh {
            qh.copy_from_slice(&qrow[h * hd..(h + 1) * hd]);
            kh.copy_from_slice(&krow[h * hd..(h + 1) * hd]);
            rope_in_place(&mut qh, pos, &self.rope_inv_freq);
            rope_in_place(&mut kh, pos, &self.rope_inv_freq);
            let lay = cache.layer_mut(li);
            lay.k.push(&kh);
            lay.v.push(&vrow[h * hd..(h + 1) * hd]);
            for (t, w) in weights.iter_mut().enumerate() {
                *w = lay.k.dot(t * nh + h, &qh) / shd;
            }
            softmax_in_place(&mut weights);
            let out_h = &mut out[h * hd..(h + 1) * hd];
            for (t, &w) in weights.iter().enumerate() {
                lay.v.axpy_into(t * nh + h, w, out_h);
            }
        }
    }
}

// ---- per-row math shared by every engine path -----------------------------

/// RMSNorm (per-channel scale) or SSNorm (scalar gamma), matching the
/// graph kernels' formulas (`ref.rmsnorm_ref` / `ref.ssnorm_ref`).
fn norm_row(row: &mut [f32], scale: &Tensor, ss: bool) {
    if ss {
        let norm = (row.iter().map(|v| v * v).sum::<f32>() + 1e-6).sqrt();
        let g = scale.data()[0];
        for v in row.iter_mut() {
            *v = g * *v / norm;
        }
    } else {
        let ms = row.iter().map(|v| v * v).sum::<f32>()
            / row.len() as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (v, s) in row.iter_mut().zip(scale.data()) {
            *v *= s * inv;
        }
    }
}

/// Per-token RTN fake-quantization (the evalq activation tap):
/// `scale = absmax / levels + 1e-8`, values snapped to the symmetric
/// grid through the one shared [`crate::quant::rtn::rtn_code`] helper
/// (the parity contract depends on every snap site agreeing). With the
/// "off" levels (2^20) this is numerically the identity, exactly like
/// the graph.
fn fake_quant_row(row: &mut [f32], levels: f32) {
    let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let scale = absmax / levels + kv::KV_EPS;
    for v in row.iter_mut() {
        *v = crate::quant::rtn::rtn_code(*v, scale, levels) as f32 * scale;
    }
}

/// Rotary embedding of one head row at absolute position `pos`
/// (half-split layout, matching `model._rope`; frequencies come from
/// the model's precomputed `theta^(-j/half)` table).
fn rope_in_place(head: &mut [f32], pos: usize, inv_freq: &[f32]) {
    let half = head.len() / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for j in 0..half {
        let angle = pos as f32 * inv_freq[j];
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (head[j], head[half + j]);
        head[j] = a * cos - b * sin;
        head[half + j] = a * sin + b * cos;
    }
}

fn softmax_in_place(w: &mut [f32]) {
    let m = w.iter().cloned().fold(f32::MIN, f32::max);
    let mut z = 0.0f32;
    for v in w.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    for v in w.iter_mut() {
        *v /= z;
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Greedy argmax over a logits row (lowest index wins ties —
/// deterministic).
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate().skip(1) {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Sample from softmax(logits / temperature); `temperature <= 0` is
/// greedy.
pub fn sample_token(row: &[f32], temperature: f32, rng: &mut Pcg) -> i32 {
    if temperature <= 0.0 {
        return argmax(row);
    }
    let mut probs: Vec<f32> = row.iter().map(|v| v / temperature).collect();
    softmax_in_place(&mut probs);
    let u = rng.uniform() as f32;
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> InferConfig {
        InferConfig { vocab_size: 96, d_model: 32, n_layers: 2, n_heads: 2,
                      d_ff: 48, rope_theta: 10000.0, norm_ss: true,
                      embproj: false }
    }

    #[test]
    fn arch_knobs_parse() {
        assert_eq!(InferConfig::arch_knobs("rmsnorm_plain").unwrap(),
                   (false, false));
        assert_eq!(InferConfig::arch_knobs("ssnorm_embproj").unwrap(),
                   (true, true));
        assert!(InferConfig::arch_knobs("bogus").is_err());
    }

    #[test]
    fn synthetic_roundtrip_through_qparams() {
        let m = InferModel::synthetic(&tiny_cfg(), 3);
        assert_eq!(m.cfg.vocab_size, 96);
        let q = m.quantized(4);
        assert!(q.weight_bytes() * 3 < m.weight_bytes(),
                "{} vs {}", q.weight_bytes(), m.weight_bytes());
    }

    #[test]
    fn forward_step_shapes_and_cache_growth() {
        let m = InferModel::synthetic(&tiny_cfg(), 5);
        let mut caches = vec![m.new_cache(4), m.new_cache(4)];
        let logits = m.forward_step(None, &[1, 2], &mut caches, 4);
        assert_eq!(logits.shape(), &[2, 96]);
        assert_eq!(caches[0].n_tokens(), 1);
        let logits = m.forward_step(None, &[3, 4], &mut caches, 4);
        assert_eq!(logits.shape(), &[2, 96]);
        assert_eq!(caches[1].n_tokens(), 2);
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.5, 1.0, 1.0, 0.1]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn sample_greedy_at_zero_temperature() {
        let mut rng = Pcg::new(1, 0);
        let row = [0.1f32, 3.0, -1.0];
        assert_eq!(sample_token(&row, 0.0, &mut rng), 1);
        // Positive temperature samples valid indices.
        for _ in 0..50 {
            let t = sample_token(&row, 1.0, &mut rng);
            assert!((0..3).contains(&t));
        }
    }

    #[test]
    fn from_qparams_rejects_bad_counts() {
        // 5 leaves cannot be 1 embed + 9k layer leaves + 2 tail.
        let dense: Vec<Tensor> = vec![Tensor::zeros(&[4, 4]); 5];
        assert!(InferModel::from_dense_params("rmsnorm_plain", &dense, 2,
                                              1e4)
                .is_err());
    }
}
