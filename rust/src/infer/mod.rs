//! Inference serving layer (DESIGN.md §8-§9): the continuous-batching
//! decode scheduler ([`engine`]) on top of the shared host model layer
//! ([`crate::model`]).
//!
//! The forward pass itself — [`InferModel::forward_block`] and its
//! single-token [`InferModel::decode_step`] wrapper, the quantized KV
//! cache, and the per-row kernels — lives in `rust/src/model/` and is
//! shared with the engine-free evaluator (`eval::host`). This module
//! keeps the serving-specific machinery: request queueing, step-level
//! admission/eviction, chunked prefill, and sampling parameters. The
//! historical `infer::...` paths for the model types remain valid via
//! the re-exports below.
//!
//! Parity contract (pinned by `rust/tests/infer_properties.rs` and
//! `rust/tests/model_properties.rs`):
//!
//! * Decoding on packed weights is bit-identical to decoding on their
//!   dequantized f32 twins — the fused kernels share the dense kernels'
//!   accumulation order, and the packed KV cache stores exactly the
//!   fake-quantized values the dense cache holds.
//! * Serial and pool-parallel decode are bit-identical for any worker
//!   count, and a sequence's token stream is independent of batch
//!   composition, so the continuous-batching scheduler never changes
//!   results.
//! * Prefill chunk size never changes results: admitting a prompt in
//!   blocks of 64 yields the same KV contents and token streams as one
//!   token per step.

pub mod engine;
pub mod kv;

pub use crate::model::{argmax, sample_token, sample_token_filtered,
                       InferConfig, InferModel, KurtProbe, Linear,
                       LogitsMode, SeqBlock};
pub use engine::{DecodeEngine, DecodeParams, GenRequest, GenResult};
