//! Seeded property-testing harness (proptest is not in the offline vendor
//! set). Generates N random cases from a deterministic PCG stream and
//! reports the failing seed so any failure is reproducible with
//! `case_seed`.

use super::rng::Pcg;

/// Run `check` over `n` generated cases. On failure, panics with the case
/// index and per-case seed for reproduction.
pub fn check<G, T, C>(name: &str, n: usize, seed: u64, gen: G, check: C)
where
    G: Fn(&mut Pcg) -> T,
    C: Fn(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for i in 0..n {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg::new(case_seed, 17);
        let case = gen(&mut rng);
        if let Err(msg) = check(&case) {
            panic!(
                "property '{name}' failed on case {i} (case_seed={case_seed}):\n\
                 {msg}\ncase: {case:#?}"
            );
        }
    }
}

/// Reproduce a single case by seed (paste from a failure message).
pub fn case_seed<G, T>(seed: u64, gen: G) -> T
where
    G: Fn(&mut Pcg) -> T,
{
    let mut rng = Pcg::new(seed, 17);
    gen(&mut rng)
}

/// Convenience assertions returning Result<(), String> for use in checks.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

pub fn close(a: f32, b: f32, tol: f32) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("not close: {a} vs {b} (tol {tol})"))
    }
}

pub fn all_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        let counter = std::cell::Cell::new(0);
        check("counts", 25, 7, |rng| rng.below(10), |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, 1, |rng| rng.below(100), |&x| {
            if x < 1000 {
                Err(format!("x was {x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5).is_ok());
        assert!(close(1.0, 1.1, 1e-5).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }

    #[test]
    fn deterministic_cases() {
        let a = case_seed(123, |rng| (0..4).map(|_| rng.below(50))
                          .collect::<Vec<_>>());
        let b = case_seed(123, |rng| (0..4).map(|_| rng.below(50))
                          .collect::<Vec<_>>());
        assert_eq!(a, b);
    }
}
