//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated help text.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse argv (excluding the binary name). The first non-flag token
    /// becomes the subcommand when `with_subcommand` is set.
    pub fn parse(argv: &[String], with_subcommand: bool) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                    a.present.push(k.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(rest.to_string(), v.clone());
                    a.present.push(rest.to_string());
                } else {
                    a.flags.insert(rest.to_string(), "true".to_string());
                    a.present.push(rest.to_string());
                }
            } else if with_subcommand && a.subcommand.is_none() {
                a.subcommand = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn from_env(with_subcommand: bool) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, with_subcommand)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects a number, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a bool, got '{v}'"),
            None => default,
        }
    }

    /// Comma-separated list value.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = Args::parse(&argv("train --steps 100 --run-dir runs/x --fast"),
                            true);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.str_or("run-dir", ""), "runs/x");
        assert!(a.has("fast"));
        assert!(a.bool_or("fast", false));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = Args::parse(&argv("eval ckpt1 --bits=4 ckpt2"), true);
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.positional, vec!["ckpt1", "ckpt2"]);
        assert_eq!(a.usize_or("bits", 16), 4);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(""), false);
        assert_eq!(a.f64_or("lr", 1e-3), 1e-3);
        assert_eq!(a.list_or("opts", &["adam", "muon"]),
                   vec!["adam", "muon"]);
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&argv("--opts adam,muon,osp"), false);
        assert_eq!(a.list_or("opts", &[]), vec!["adam", "muon", "osp"]);
    }

    #[test]
    fn negative_number_value() {
        let a = Args::parse(&argv("--offset=-3.5"), false);
        assert_eq!(a.f64_or("offset", 0.0), -3.5);
    }
}
