//! Fixed-size worker thread pool with bounded work queues (backpressure).
//!
//! Stands in for tokio in the offline build. Used by the data pipeline's
//! prefetcher, the coordinator's simulated data-parallel / optimizer-
//! parallel ranks, and — through [`crate::tensor::par`] — the shared
//! parallel kernel layer (DESIGN.md §6). Queue bounds give the
//! backpressure property the coordinator tests rely on: a slow consumer
//! blocks producers instead of letting queues grow without bound.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IN_POOL_WORKER: std::cell::Cell<bool> =
        std::cell::Cell::new(false);
}

/// True when the calling thread is a [`ThreadPool`] worker (of any pool).
/// The parallel kernels in [`crate::tensor::par`] consult this to fall
/// back to serial execution instead of issuing a nested scatter: a job
/// that blocks waiting for sub-jobs on the same pool can starve the queue
/// once every worker is blocked the same way.
pub fn on_worker_thread() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

/// (completed count, any job panicked) shared between a scatter call
/// and its jobs. The guard increments on drop, so a panicking job still
/// unblocks the waiting caller, which then re-raises on its own thread.
type DoneState = (Mutex<(usize, bool)>, Condvar);

struct DoneGuard(Arc<DoneState>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let (lock, cv) = &*self.0;
        let mut st = lock.lock().unwrap();
        st.0 += 1;
        if std::thread::panicking() {
            st.1 = true;
        }
        drop(st);
        cv.notify_all();
    }
}

/// Block until `n` jobs completed; panic if any of them panicked.
fn wait_all(done: &DoneState, n: usize, who: &str) {
    let (lock, cv) = done;
    let mut st = lock.lock().unwrap();
    while st.0 < n {
        st = cv.wait(st).unwrap();
    }
    let panicked = st.1;
    drop(st);
    if panicked {
        panic!("{who}: a job panicked");
    }
}

struct Queue {
    jobs: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    deque: VecDeque<Job>,
    shutdown: bool,
}

/// A scoped-less thread pool: jobs must be 'static. Results come back via
/// the channels the caller closes over (see `scatter`).
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    n_workers: usize,
}

impl ThreadPool {
    /// `capacity` bounds the pending-job queue (backpressure); it must be
    /// at least 1.
    pub fn new(n_workers: usize, capacity: usize) -> ThreadPool {
        assert!(n_workers > 0 && capacity > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { deque: VecDeque::new(),
                                          shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        });
        let workers = (0..n_workers)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("osp-worker-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers, n_workers }
    }

    /// Number of worker threads (partitioning hint for block kernels).
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Submit a job; blocks while the queue is full (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, job: Job) {
        let mut st = self.queue.jobs.lock().unwrap();
        while st.deque.len() >= self.queue.capacity {
            st = self.queue.not_full.wait(st).unwrap();
        }
        assert!(!st.shutdown, "submit after shutdown");
        st.deque.push_back(job);
        drop(st);
        self.queue.not_empty.notify_one();
    }

    /// Current queue depth (for the backpressure property tests).
    pub fn queue_depth(&self) -> usize {
        self.queue.jobs.lock().unwrap().deque.len()
    }

    /// Run `f` over each item on the pool and collect results in input
    /// order. Blocks until all items finish.
    ///
    /// Ordering guarantee: `result[i] == f(i, items[i])` for every `i`,
    /// regardless of completion order. The guarantee is positional by
    /// construction — each job writes its result into slot `i` of a
    /// pre-sized buffer — and does **not** depend on any channel or queue
    /// ordering. `scatter_ordering_under_skew` (tests) pins this down
    /// with deliberately inverted completion order.
    pub fn scatter<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done: Arc<DoneState> =
            Arc::new((Mutex::new((0usize, false)), Condvar::new()));
        let f = Arc::new(f);
        for (i, item) in items.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            let f = Arc::clone(&f);
            self.submit(move || {
                // Drop-guard: a panicking f still advances the counter,
                // so the caller unblocks and re-raises instead of
                // hanging forever.
                let _guard = DoneGuard(done);
                let r = f(i, item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        wait_all(&done, n, "scatter");
        // Workers may still hold their Arc clone for a moment after the
        // final notify; extract through the lock rather than try_unwrap.
        let mut guard = results.lock().unwrap();
        std::mem::take(&mut *guard)
            .into_iter()
            .map(|r| r.expect("missing scatter result"))
            .collect()
    }

    /// Run `f(chunk_index, chunk)` over disjoint, contiguous
    /// `chunk_len`-sized mutable chunks of `out` (the last chunk may be
    /// shorter), blocking until every chunk completes. Unlike
    /// [`ThreadPool::scatter`], `out` and `f` may borrow from the
    /// caller's stack: the method only returns once all chunk jobs have
    /// finished, so the borrows remain valid for the jobs' whole
    /// lifetime. This is the shared-handle plumbing behind the parallel
    /// kernels in [`crate::tensor::par`].
    ///
    /// Determinism: chunk boundaries depend only on `out.len()` and
    /// `chunk_len` (never on worker count or scheduling) and each chunk
    /// is written by exactly one job, so the result is bit-identical to
    /// running `f` over the chunks serially in index order.
    ///
    /// Panics (after all jobs settle) if any chunk job panicked. Must not
    /// be called from a job running on the *same* pool — see
    /// [`on_worker_thread`].
    pub fn scatter_chunks<T, F>(&self, out: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "scatter_chunks: chunk_len must be > 0");
        let n_chunks = out.len().div_ceil(chunk_len);
        if n_chunks <= 1 {
            if !out.is_empty() {
                f(0, out);
            }
            return;
        }

        // Raw shared view of the output and the (borrowed) kernel. Safe
        // because chunk ranges are disjoint and we block below until
        // every job has dropped its access.
        struct Shared<T, F> {
            base: *mut T,
            len: usize,
            chunk_len: usize,
            f: *const F,
        }
        impl<T, F> Clone for Shared<T, F> {
            fn clone(&self) -> Self {
                *self
            }
        }
        impl<T, F> Copy for Shared<T, F> {}
        unsafe impl<T: Send, F: Sync> Send for Shared<T, F> {}

        let done: Arc<DoneState> =
            Arc::new((Mutex::new((0usize, false)), Condvar::new()));
        let shared = Shared {
            base: out.as_mut_ptr(),
            len: out.len(),
            chunk_len,
            f: &f,
        };
        for c in 0..n_chunks {
            let done = Arc::clone(&done);
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let _guard = DoneGuard(done);
                let s0 = c * shared.chunk_len;
                let s1 = (s0 + shared.chunk_len).min(shared.len);
                // Safety: [s0, s1) ranges are disjoint across jobs and
                // the caller outlives them (blocks on `done` below).
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut(shared.base.add(s0),
                                                   s1 - s0)
                };
                unsafe { (*shared.f)(c, chunk) };
            });
            // Safety: lifetime erasure only (the fat-pointer layout is
            // identical) — we wait for every job before returning, so
            // the borrows in `job` stay valid.
            let job: Job = unsafe { std::mem::transmute(job) };
            self.submit_boxed(job);
        }
        wait_all(&done, n_chunks, "scatter_chunks");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.jobs.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.not_empty.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(q: Arc<Queue>) {
    IN_POOL_WORKER.with(|f| f.set(true));
    loop {
        let job = {
            let mut st = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.deque.pop_front() {
                    q.not_full.notify_one();
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = q.not_empty.wait(st).unwrap();
            }
        };
        // A panicking job must not take the worker down with it: scatter
        // callers are notified through their completion guards and
        // re-raise on their own thread.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

/// A bounded MPSC channel built on the same primitives; used for the
/// prefetching batch iterator (producer thread -> training loop).
/// Constructor-only type: all state lives in the Sender/Receiver halves.
pub struct BoundedChannel<T>(std::marker::PhantomData<T>);

struct ChannelInner<T> {
    buf: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct ChannelState<T> {
    deque: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedChannel<T> {
    pub fn new(capacity: usize) -> (Sender<T>, Receiver<T>) {
        assert!(capacity > 0);
        let inner = Arc::new(ChannelInner {
            buf: Mutex::new(ChannelState { deque: VecDeque::new(),
                                           closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        });
        (Sender { inner: Arc::clone(&inner) }, Receiver { inner })
    }
}

pub struct Sender<T> {
    inner: Arc<ChannelInner<T>>,
}

pub struct Receiver<T> {
    inner: Arc<ChannelInner<T>>,
}

impl<T> Sender<T> {
    /// Blocks while full. Returns Err(item) if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.buf.lock().unwrap();
        while st.deque.len() >= self.inner.capacity && !st.closed {
            st = self.inner.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(item);
        }
        st.deque.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        self.inner.buf.lock().unwrap().closed = true;
        self.inner.not_empty.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocks until an item arrives; None when the sender closed and the
    /// buffer drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.buf.lock().unwrap();
        loop {
            if let Some(item) = st.deque.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.buf.lock().unwrap().deque.len()
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.inner.buf.lock().unwrap().closed = true;
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_preserves_order() {
        let pool = ThreadPool::new(4, 16);
        let out = pool.scatter((0..100).collect(), |_i, x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let pool = ThreadPool::new(3, 4);
        let counter = Arc::new(AtomicUsize::new(0));
        let n = 200;
        let _ = pool.scatter(
            (0..n).collect::<Vec<usize>>(),
            {
                let counter = Arc::clone(&counter);
                move |_i, _x| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }
            },
        );
        assert_eq!(counter.load(Ordering::SeqCst), n);
    }

    #[test]
    fn scatter_ordering_under_skew() {
        // Make early indices finish *last*: results must still map back
        // to input indices (the documented positional guarantee).
        let pool = ThreadPool::new(4, 32);
        let out = pool.scatter((0..24).collect(), |i, x: u64| {
            std::thread::sleep(std::time::Duration::from_millis(
                (24 - i as u64) % 7));
            x * 10 + 1
        });
        assert_eq!(out, (0..24).map(|x| x * 10 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_chunks_covers_all_chunks_once() {
        let pool = ThreadPool::new(3, 8);
        let mut out = vec![0u32; 103]; // non-multiple of chunk_len
        pool.scatter_chunks(&mut out, 10, |ci, chunk| {
            assert!(chunk.len() == 10 || ci == 10);
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 10 + j) as u32 + 1;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1);
        }
    }

    #[test]
    fn scatter_chunks_borrows_caller_state() {
        // The whole point of scatter_chunks: kernels may close over
        // non-'static stack data.
        let pool = ThreadPool::new(4, 16);
        let src: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let mut dst = vec![0.0f32; 256];
        pool.scatter_chunks(&mut dst, 32, |ci, chunk| {
            let s0 = ci * 32;
            for (d, s) in chunk.iter_mut().zip(&src[s0..s0 + chunk.len()]) {
                *d = 2.0 * s;
            }
        });
        for (i, v) in dst.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
    }

    #[test]
    fn scatter_chunks_propagates_panics() {
        let pool = ThreadPool::new(2, 8);
        let mut out = vec![0u8; 64];
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.scatter_chunks(&mut out, 8, |ci, _chunk| {
                    if ci == 3 {
                        panic!("boom");
                    }
                });
            }));
        assert!(r.is_err());
        // Workers survive the panic: the pool still runs jobs.
        let sum = pool.scatter(vec![1u32, 2, 3], |_i, x| x).iter()
            .sum::<u32>();
        assert_eq!(sum, 6);
    }

    #[test]
    fn scatter_propagates_panics_instead_of_hanging() {
        let pool = ThreadPool::new(2, 8);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = pool.scatter((0..8).collect(), |i, x: u32| {
                    if i == 5 {
                        panic!("boom");
                    }
                    x
                });
            }));
        assert!(r.is_err());
        // The pool is still serviceable afterwards.
        let out = pool.scatter(vec![7u32], |_i, x| x + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn worker_thread_flag() {
        assert!(!on_worker_thread());
        let pool = ThreadPool::new(2, 4);
        let flags = pool.scatter(vec![(), ()], |_i, ()| on_worker_thread());
        assert!(flags.iter().all(|&f| f));
        assert!(!on_worker_thread());
    }

    #[test]
    fn channel_fifo_and_close() {
        let (tx, rx) = BoundedChannel::new(2);
        let producer = std::thread::spawn(move || {
            for i in 0..50 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        producer.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn channel_capacity_bounds_depth() {
        let (tx, rx) = BoundedChannel::new(3);
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.depth(), 3);
        // A 4th send must block: do it from a thread and verify it only
        // completes after a recv.
        let t = std::thread::spawn(move || tx.send(99).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(rx.depth(), 3); // still bounded
        assert_eq!(rx.recv(), Some(0));
        t.join().unwrap();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(99));
    }

    #[test]
    fn receiver_drop_unblocks_sender() {
        let (tx, rx) = BoundedChannel::new(1);
        tx.send(1).unwrap();
        drop(rx);
        assert!(tx.send(2).is_err());
    }
}
