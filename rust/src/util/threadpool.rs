//! Fixed-size worker thread pool with bounded work queues (backpressure).
//!
//! Stands in for tokio in the offline build. Used by the data pipeline's
//! prefetcher and the coordinator's simulated data-parallel / optimizer-
//! parallel ranks. Queue bounds give the backpressure property the
//! coordinator tests rely on: a slow consumer blocks producers instead of
//! letting queues grow without bound.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueState {
    deque: VecDeque<Job>,
    shutdown: bool,
}

/// A scoped-less thread pool: jobs must be 'static. Results come back via
/// the channels the caller closes over (see `scatter`).
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `capacity` bounds the pending-job queue (backpressure); it must be
    /// at least 1.
    pub fn new(n_workers: usize, capacity: usize) -> ThreadPool {
        assert!(n_workers > 0 && capacity > 0);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { deque: VecDeque::new(),
                                          shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        });
        let workers = (0..n_workers)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("osp-worker-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Submit a job; blocks while the queue is full (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.queue.jobs.lock().unwrap();
        while st.deque.len() >= self.queue.capacity {
            st = self.queue.not_full.wait(st).unwrap();
        }
        assert!(!st.shutdown, "submit after shutdown");
        st.deque.push_back(Box::new(f));
        drop(st);
        self.queue.not_empty.notify_one();
    }

    /// Current queue depth (for the backpressure property tests).
    pub fn queue_depth(&self) -> usize {
        self.queue.jobs.lock().unwrap().deque.len()
    }

    /// Run `f` over each item on the pool and collect results in input
    /// order. Blocks until all items finish.
    pub fn scatter<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let f = Arc::new(f);
        for (i, item) in items.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            let f = Arc::clone(&f);
            self.submit(move || {
                let r = f(i, item);
                results.lock().unwrap()[i] = Some(r);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (lock, cv) = &*done;
        let mut finished = lock.lock().unwrap();
        while *finished < n {
            finished = cv.wait(finished).unwrap();
        }
        drop(finished);
        // Workers may still hold their Arc clone for a moment after the
        // final notify; extract through the lock rather than try_unwrap.
        let mut guard = results.lock().unwrap();
        std::mem::take(&mut *guard)
            .into_iter()
            .map(|r| r.expect("missing scatter result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.jobs.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.not_empty.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut st = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.deque.pop_front() {
                    q.not_full.notify_one();
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = q.not_empty.wait(st).unwrap();
            }
        };
        job();
    }
}

/// A bounded MPSC channel built on the same primitives; used for the
/// prefetching batch iterator (producer thread -> training loop).
/// Constructor-only type: all state lives in the Sender/Receiver halves.
pub struct BoundedChannel<T>(std::marker::PhantomData<T>);

struct ChannelInner<T> {
    buf: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct ChannelState<T> {
    deque: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedChannel<T> {
    pub fn new(capacity: usize) -> (Sender<T>, Receiver<T>) {
        assert!(capacity > 0);
        let inner = Arc::new(ChannelInner {
            buf: Mutex::new(ChannelState { deque: VecDeque::new(),
                                           closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        });
        (Sender { inner: Arc::clone(&inner) }, Receiver { inner })
    }
}

pub struct Sender<T> {
    inner: Arc<ChannelInner<T>>,
}

pub struct Receiver<T> {
    inner: Arc<ChannelInner<T>>,
}

impl<T> Sender<T> {
    /// Blocks while full. Returns Err(item) if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.buf.lock().unwrap();
        while st.deque.len() >= self.inner.capacity && !st.closed {
            st = self.inner.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(item);
        }
        st.deque.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        self.inner.buf.lock().unwrap().closed = true;
        self.inner.not_empty.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocks until an item arrives; None when the sender closed and the
    /// buffer drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.buf.lock().unwrap();
        loop {
            if let Some(item) = st.deque.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.buf.lock().unwrap().deque.len()
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.inner.buf.lock().unwrap().closed = true;
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_preserves_order() {
        let pool = ThreadPool::new(4, 16);
        let out = pool.scatter((0..100).collect(), |_i, x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let pool = ThreadPool::new(3, 4);
        let counter = Arc::new(AtomicUsize::new(0));
        let n = 200;
        let _ = pool.scatter(
            (0..n).collect::<Vec<usize>>(),
            {
                let counter = Arc::clone(&counter);
                move |_i, _x| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }
            },
        );
        assert_eq!(counter.load(Ordering::SeqCst), n);
    }

    #[test]
    fn channel_fifo_and_close() {
        let (tx, rx) = BoundedChannel::new(2);
        let producer = std::thread::spawn(move || {
            for i in 0..50 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        producer.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn channel_capacity_bounds_depth() {
        let (tx, rx) = BoundedChannel::new(3);
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.depth(), 3);
        // A 4th send must block: do it from a thread and verify it only
        // completes after a recv.
        let t = std::thread::spawn(move || tx.send(99).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(rx.depth(), 3); // still bounded
        assert_eq!(rx.recv(), Some(0));
        t.join().unwrap();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(99));
    }

    #[test]
    fn receiver_drop_unblocks_sender() {
        let (tx, rx) = BoundedChannel::new(1);
        tx.send(1).unwrap();
        drop(rx);
        assert!(tx.send(2).is_err());
    }
}
