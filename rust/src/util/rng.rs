//! PCG64-based pseudo-random number generation.
//!
//! Deterministic and splittable-by-stream: every consumer of randomness
//! (data shards, property tests, quantization rotations) derives its own
//! stream from a seed + stream id, so runs are reproducible regardless of
//! thread interleaving.

/// PCG-XSH-RR 64/32 with a 64-bit state extension (two 32-bit draws per
/// 64-bit output). Small, fast, and statistically solid for simulation.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream id. Distinct streams
    /// are guaranteed distinct sequences (the increment must be odd).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child generator; used to hand independent streams to
    /// worker threads without sharing state.
    pub fn split(&mut self, salt: u64) -> Pcg {
        let seed = self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        Pcg::new(seed, salt.wrapping_add(0x5851F42D4C957F2D))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo
    /// bias (matters for the Zipf sampler's tail fidelity).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(7, 2);
        assert_ne!(Pcg::new(7, 1).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_centered() {
        let mut rng = Pcg::new(1, 0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg::new(3, 0);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(11, 0);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(5, 0);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Pcg::new(9, 0);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[rng.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Pcg::new(42, 0);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let sa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(sa, sb);
    }
}
