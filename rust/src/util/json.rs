//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest, run configs, and telemetry).
//!
//! Built in-tree because serde is not in the offline vendor set. Supports
//! the full JSON value model; numbers are f64 (the manifest only carries
//! shapes/ids well inside f64's exact-integer range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifest
    /// loading uses this so failures are self-describing.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- serialization ---------------------------------------------------

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no inf/nan; telemetry maps them to null.
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs: manifest never emits them,
                            // but handle for completeness.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let hex2 = self
                                        .b
                                        .get(self.i..self.i + 4)
                                        .ok_or_else(|| self.err("short \\u"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2).map_err(
                                            |_| self.err("bad \\u"),
                                        )?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad \\u"))?;
                                    self.i += 4;
                                    0x10000
                                        + ((code - 0xD800) << 10)
                                        + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                code
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("truncated utf8"))?;
                        let st = std::str::from_utf8(bytes)
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(st);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if self.i == start {
            return Err(self.err("expected value"));
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version":1,"artifacts":{"a":{"shape":[2,3],
            "dtype":"f32","neg":-1.5e-3}},"flag":true,"none":null}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let a = j.get("artifacts").unwrap().get("a").unwrap();
        assert_eq!(a.get("shape").unwrap().usize_arr(), Some(vec![2, 3]));
        assert_eq!(a.get("dtype").unwrap().as_str(), Some("f32"));
        assert!((a.get("neg").unwrap().as_f64().unwrap() + 0.0015).abs()
            < 1e-12);
        let re = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn strings_with_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
        let re = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ∑\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ∑"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integers_serialize_without_dot() {
        assert_eq!(Json::num(42.0).dump(), "42");
        assert_eq!(Json::num(-0.5).dump(), "-0.5");
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_usize(),
                   Some(4));
    }

    #[test]
    fn req_errors_name_key() {
        let j = Json::parse("{}").unwrap();
        let e = j.req("missing_thing").unwrap_err();
        assert!(e.msg.contains("missing_thing"));
    }
}
