//! Hand-built substrates: the offline build environment vendors only the
//! `xla` crate's dependency closure, so JSON, RNG, CLI parsing, a thread
//! pool, and property testing are implemented here (and tested like any
//! other module).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
