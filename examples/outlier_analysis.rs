//! Outlier & attention-sink analysis (paper §5.2, Figures 2, 5, 6, 8-11):
//! activation/weight histograms, massive-activation (6-sigma) detection,
//! sink-head identification, and the sink-logit strategy comparison
//! between Adam and OSP checkpoints.
//!
//!   cargo run --release --example outlier_analysis
//!   cargo run --release --example outlier_analysis -- --tags adam,muon,osp

use std::path::PathBuf;

use anyhow::Result;

use osp::repro;
use osp::runtime::Engine;
use osp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let engine = Engine::open(std::path::Path::new(
        &args.str_or("artifacts", "artifacts")))?;
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    let tags = args.list_or("tags", &["adam", "osp"]);
    let tag_refs: Vec<&str> = tags.iter().map(|s| s.as_str()).collect();

    // Figure 2 + Figures 8-9: activation histograms at probed depths.
    println!("{}", repro::fig2(&engine, &runs_dir, &tag_refs)?);
    // Figures 10-11: weight histograms.
    println!("{}", repro::fig1011(&engine, &runs_dir, &tag_refs)?);
    // Figures 5-6 + §5.2: attention sinks without outliers.
    println!("{}", repro::fig56(&engine, &runs_dir, &tag_refs)?);
    Ok(())
}
