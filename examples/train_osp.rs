//! End-to-end driver (deliverable (b) + the DESIGN.md validation run):
//! trains the paper's ablation grid (Adam baseline ... full OSP) on the
//! synthetic corpus, logging loss + excess-kurtosis curves, saving
//! checkpoints, then evaluating every run at fp16 and under 4-bit
//! quantization — the Figure 3 / Table 2 / Table 3 pipeline in one
//! command.
//!
//!   cargo run --release --example train_osp -- --ablation --steps 300
//!   cargo run --release --example train_osp -- --steps 200   # adam+osp
//!
//! Also demonstrates the systems modes:
//!   --dp-ranks 2           simulated data parallelism (ring all-reduce)
//!   --disaggregated true   the paper's optimizer-parallel Muon

use std::path::PathBuf;

use anyhow::Result;

use osp::bench::{fmt_pct, fmt_ppl, Table};
use osp::config::{TrainConfig, ABLATION_GRID};
use osp::coordinator::Trainer;
use osp::eval::BitConfig;
use osp::repro::{self, Effort};
use osp::runtime::Engine;
use osp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let engine = Engine::open(std::path::Path::new(
        &args.str_or("artifacts", "artifacts")))?;
    let steps = args.u64_or("steps", 300);
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));

    let grid: Vec<(&str, &str, &str)> = if args.bool_or("ablation", false) {
        ABLATION_GRID.to_vec()
    } else {
        vec![("adam", "adam", "rmsnorm_plain"),
             ("osp", "muon", "ssnorm_embproj")]
    };

    // ---- phase 1: training runs (Figure 3/7 telemetry) ----
    for (tag, optimizer, arch) in &grid {
        let run_dir = runs_dir.join(tag);
        if !osp::checkpoint::list_steps(&run_dir).is_empty()
            && !args.bool_or("force", false)
        {
            println!("[{tag}] found existing checkpoints — skipping \
                      (use --force to retrain)");
            continue;
        }
        let mut t = vec![
            "--optimizer".to_string(), optimizer.to_string(),
            "--arch".to_string(), arch.to_string(),
            "--steps".to_string(), steps.to_string(),
            "--run-dir".to_string(), run_dir.to_string_lossy().into_owned(),
            "--ckpt-every".to_string(), (steps / 3).max(1).to_string(),
            "--eval-every".to_string(),
            args.str_or("eval-every", "25"),
        ];
        for flag in ["dp-ranks", "grad-accum", "disaggregated", "lr",
                     "seed"] {
            if let Some(v) = args.get(flag) {
                t.push(format!("--{flag}"));
                t.push(v.to_string());
            }
        }
        let cfg = TrainConfig::from_args(&Args::parse(&t, false));
        println!("=== {tag}: {optimizer} @ {arch}, {steps} steps ===");
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        let s = trainer.run()?;
        println!(
            "[{tag}] loss {:.4} -> ppl {:.2} | kurt_max {:+.2} | \
             {:.0} tok/s ({:.1}s)",
            s.final_loss, s.final_ppl, s.final_kurt_max, s.tokens_per_sec,
            s.wall_secs);
        for (phase, n, secs) in trainer.profiler.report() {
            println!("    {phase:12} x{n:<5} {secs:7.2}s");
        }
    }

    // ---- phase 2: the headline comparison (Figure 1 / Table 2 slice) ----
    let effort = if args.bool_or("full", false) {
        Effort::FULL
    } else {
        Effort::QUICK
    };
    let tags: Vec<&str> = grid.iter().map(|&(t, _, _)| t).collect();
    let runs = repro::load_runs(&runs_dir, &tags)?;
    let mut table = Table::new(
        "E2E summary — fp16 vs 4-bit (RTN, W4-A4-KV4)",
        &["run", "kurt_max", "fp16 avg", "fp16 ppl", "4bit avg",
          "4bit ppl"]);
    for run in &runs {
        let fp = osp::eval::perplexity(&engine, &run.arch, &run.params, 16,
                                       16, 0.0, effort.ppl_batches)?;
        let (_r, fp_avg) = osp::eval::tasks::run_suite(
            &engine, &run.arch, &run.params, effort.n_per_task, 16, 16,
            0.0, 99)?;
        let (q_avg, q_ppl, _) = repro::eval_bitconfig(
            &engine, run, BitConfig::new(4, 4, 4), false, effort)?;
        table.row(vec![
            run.tag.clone(),
            format!("{:+.2}", fp.kurt_max),
            fmt_pct(fp_avg),
            fmt_ppl(fp.ppl),
            fmt_pct(q_avg),
            fmt_ppl(q_ppl),
        ]);
    }
    table.print();
    println!("{}", repro::fig3(&runs_dir, &tags)?);
    println!("telemetry + checkpoints in {}", runs_dir.display());
    Ok(())
}
