//! PTQ composition study (paper Table 4): apply RTN, FFN-Had, GPTQ,
//! QuaRot-lite, and SpinQuant-lite to trained checkpoints and compare
//! W4-A4-KV4 perplexity — showing OSP models both need PTQ less and still
//! compose with it.
//!
//!   cargo run --release --example quantize_eval            # adam vs osp
//!   cargo run --release --example quantize_eval -- --tags osp --w-bits 3

use std::path::PathBuf;

use anyhow::Result;

use osp::bench::{fmt_ppl, Table};
use osp::eval::perplexity;
use osp::quant::{self, PtqConfig, Rotation, WeightMethod};
use osp::repro;
use osp::runtime::Engine;
use osp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let engine = Engine::open(std::path::Path::new(
        &args.str_or("artifacts", "artifacts")))?;
    let runs_dir = PathBuf::from(args.str_or("runs-dir", "runs"));
    let tags = args.list_or("tags", &["adam", "osp"]);
    let tag_refs: Vec<&str> = tags.iter().map(|s| s.as_str()).collect();
    let runs = repro::load_runs(&runs_dir, &tag_refs)?;
    let w_bits = args.usize_or("w-bits", 4) as u32;
    let (a_bits, kv_bits) = (args.usize_or("a-bits", 4) as u32,
                             args.usize_or("kv-bits", 4) as u32);

    let base = PtqConfig::rtn(w_bits);
    let recipes: Vec<(&str, PtqConfig)> = vec![
        ("RTN", base),
        ("+ FFN Had", PtqConfig { ffn_had: true, ..base }),
        ("+ GPTQ", PtqConfig { method: WeightMethod::Gptq, ..base }),
        ("+ QuaRot-lite", PtqConfig { method: WeightMethod::Gptq,
                                      rotation: Rotation::Random,
                                      ffn_had: true, ..base }),
        ("+ SpinQuant-lite", PtqConfig { method: WeightMethod::Gptq,
                                         rotation: Rotation::Learned,
                                         ffn_had: true, ..base }),
    ];

    let mut headers: Vec<String> = vec!["Quantization".into()];
    headers.extend(tags.iter().cloned());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("PTQ composition — W{w_bits}-A{a_bits}-KV{kv_bits} \
                  perplexity"),
        &hdr);

    // fp16 reference row first.
    let mut fp_row = vec!["fp16 (reference)".to_string()];
    for run in &runs {
        let fp = perplexity(&engine, &run.arch, &run.params, 16, 16, 0.0,
                            2)?;
        fp_row.push(fmt_ppl(fp.ppl));
    }
    table.row(fp_row);

    for (label, cfg) in recipes {
        let mut row = vec![label.to_string()];
        for run in &runs {
            let qm = quant::prepare(&engine, &run.arch, &run.params, &cfg)?;
            let q = perplexity(&engine, &qm.arch, qm.dense_params(), a_bits,
                               kv_bits, qm.had_flag, 2)?;
            row.push(fmt_ppl(q.ppl));
        }
        table.row(row);
        println!("  finished {label}");
    }
    table.print();
    Ok(())
}
