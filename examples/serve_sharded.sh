#!/bin/sh
# Two-worker row-parallel sharded serving quickstart (DESIGN.md §14).
#
# Cuts a synthetic packed W4 model into 2 shard artifacts, boots a
# coordinator plus two `osp worker` processes that fetch their shards
# from the coordinator (checksummed, chunked, resumable), streams a
# few generations — bit-identical to a single-process server — and
# drains everything cleanly.
#
#   cd rust && cargo build --release && sh ../examples/serve_sharded.sh
#
# Swap `--synthetic ...` for `--packed qmodel.bin --n-heads N` to
# shard a real PTQ artifact (`osp quantize --ckpt DIR --save-packed
# qmodel.bin`). Sharded serving requires the integer kernel path
# (`--int scalar|auto`, A-bits <= 8): integer partial sums recombine
# exactly, f32 sums would not.
set -eu

OSP=${OSP:-./target/release/osp}
MODEL="--synthetic --w-bits 4 --a-bits 4 --kv-bits 4 \
  --d-model 64 --n-layers 2 --n-heads 4 --d-ff 96"
COORD=127.0.0.1:8230
W0=127.0.0.1:8231
W1=127.0.0.1:8232
DIR=$(mktemp -d)
trap 'rm -rf "$DIR"' EXIT

# 1. Publish the shard artifacts + manifest.
$OSP shard $MODEL --shards 2 --out "$DIR/shards"

# 2. Coordinator first: it serves GET /shards immediately and gates
#    /generate until the fleet reports ready.
$OSP serve $MODEL --int auto --addr "$COORD" \
  --workers "$W0,$W1" --shard-dir "$DIR/shards" &
COORD_PID=$!

until curl -sf "http://$COORD/healthz" > /dev/null; do sleep 0.2; done

# 3. Workers fetch their shard from the coordinator and come up.
$OSP worker --shard 0 --n-shards 2 --int auto --addr "$W0" \
  --coordinator "$COORD" --spool "$DIR/shard_0.part" &
W0_PID=$!
$OSP worker --shard 1 --n-shards 2 --int auto --addr "$W1" \
  --coordinator "$COORD" --spool "$DIR/shard_1.part" &
W1_PID=$!

until curl -sf "http://$COORD/healthz" | grep -q '"ready":true'; do
  sleep 0.2
done

# 4. Generate: trunk matmuls fan out to both workers per step; the
#    token stream is bit-identical to a single-process server.
curl -s -X POST "http://$COORD/generate" \
  -d '{"prompt":[1,2,3,5],"max_new":12}'
echo
curl -s "http://$COORD/status"
echo

# 5. Drain: the coordinator finishes in-flight work, then propagates
#    the drain to the fleet; every process exits 0 with zero leaked
#    slots / stripes.
curl -s -X POST "http://$COORD/admin/drain" > /dev/null
wait "$COORD_PID" "$W0_PID" "$W1_PID"
echo "sharded fleet drained cleanly"
