//! Quickstart for the host model layer (DESIGN.md §8-§9): build a
//! synthetic model, quantize it to packed W4, serve tokens with a
//! quantized KV4 cache through the continuous-batching scheduler, and
//! ingest a *long* prompt with chunked prefill — no XLA artifacts
//! required. The same flow is available from the CLI:
//!
//!   osp generate --synthetic --w-bits 4 --a-bits 4 --kv-bits 4 --check
//!   osp generate --packed qmodel.bin --prompt "1 2 3" --max-new 16
//!   osp generate --synthetic --prompt-len 96 --prefill-chunk 64
//!   osp eval --synthetic --w-bits 4 --a-bits 4 --kv-bits 4
//!   osp serve-bench --batches 1,8,32 --json BENCH_infer.json
//!
//! Run with: cargo run --release --example generate_tokens

use osp::data::grammar::{Grammar, LANGUAGE_SEED};
use osp::eval::tasks;
use osp::infer::{engine, DecodeEngine, DecodeParams, GenRequest,
                 InferConfig, InferModel};
use osp::tensor::par;

fn main() -> anyhow::Result<()> {
    let cfg = InferConfig { vocab_size: 512, d_model: 128, n_layers: 4,
                            n_heads: 4, d_ff: 352, rope_theta: 10000.0,
                            norm_ss: true, embproj: false };
    let dense = InferModel::synthetic(&cfg, 7);
    let packed = dense.quantized(4);
    println!("weights: {} KiB dense -> {} KiB packed W4",
             dense.weight_bytes() / 1024, packed.weight_bytes() / 1024);

    // Grammar-corpus prompts, decoded greedily at the paper's 4-4-4
    // deployment point on the shared OSP_THREADS pool.
    let g = Grammar::new(cfg.vocab_size, LANGUAGE_SEED);
    let prompts = tasks::grammar_prompts(&g, 4, 8, 1);
    let params = DecodeParams::greedy(4, 4, 4);
    let mut eng = DecodeEngine::new(&packed, params, par::shared_pool());
    for (i, p) in prompts.iter().enumerate() {
        eng.submit(GenRequest { id: i, prompt: p.clone(), max_new: 16 })?;
    }
    let results = eng.run()?;
    for r in &results {
        println!("[{}] {:?} -> {:?}", r.id, prompts[r.id], r.generated);
    }
    println!("{:.0} tok/s, peak KV {} KiB", eng.stats.tokens_per_sec(),
             eng.stats.peak_kv_bytes / 1024);

    // Long-prompt generate: a 96-token prompt is ingested in prefill
    // chunks (default 64), so each packed weight row's in-register
    // dequant is amortized across the whole chunk instead of paying off
    // one token at a time. Streams are bit-identical for any chunk size
    // (the block-forward parity contract) — only wall-clock changes.
    let long_prompts = tasks::grammar_prompts(&g, 2, 96, 3);
    for chunk in [1usize, 64] {
        let p = DecodeParams { prefill_chunk: chunk,
                               ..DecodeParams::greedy(4, 4, 2) };
        let mut eng = DecodeEngine::new(&packed, p, par::shared_pool());
        for (i, lp) in long_prompts.iter().enumerate() {
            eng.submit(GenRequest { id: i, prompt: lp.clone(),
                                    max_new: 8 })?;
        }
        let outs = eng.run()?;
        println!(
            "long prompt (96 tok) @ prefill-chunk {chunk:2}: {:.0} prompt \
             tok/s over {} steps, first stream {:?}",
            eng.stats.prefill_per_sec(), eng.stats.steps,
            outs[0].generated);
    }
    // The two chunkings generate the same tokens — verify the cheap way.
    let a = engine::generate(&packed, &long_prompts, 8,
                             DecodeParams { prefill_chunk: 1,
                                            ..DecodeParams::greedy(4, 4, 2) },
                             par::shared_pool())?;
    let b = engine::generate(&packed, &long_prompts, 8,
                             DecodeParams { prefill_chunk: 64,
                                            ..DecodeParams::greedy(4, 4, 2) },
                             par::shared_pool())?;
    assert_eq!(a, b, "prefill chunking changed the streams");

    // The parity contract: the dense-f32 twin produces bit-identical
    // streams.
    let rep = tasks::generation_consistency(&packed, &g, 4, 8, 16, 4, 4,
                                            1, par::shared_pool());
    assert_eq!(rep.mismatches, 0);
    println!("packed/dense consistency: {} tokens, 100% agreement",
             rep.tokens);
    Ok(())
}
