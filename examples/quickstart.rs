//! Quickstart: open the artifact engine, train the OSP configuration for
//! a handful of steps, watch loss fall and kurtosis stay flat, and
//! evaluate held-out perplexity — the whole three-layer stack in ~40
//! lines of user code.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Host-side kernels (Newton-Schulz, rotations, GPTQ, kurtosis) run on
//! the shared parallel kernel layer (rust/DESIGN.md §6). `OSP_THREADS`
//! sets its worker count — e.g. `OSP_THREADS=8 cargo run --release
//! --example quickstart`; `OSP_THREADS=1` forces serial execution, and
//! the default is the host's available parallelism (capped at 16).

use anyhow::Result;

use osp::config::TrainConfig;
use osp::coordinator::Trainer;
use osp::runtime::Engine;
use osp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let engine = Engine::open(std::path::Path::new(
        &args.str_or("artifacts", "artifacts")))?;
    println!("preset={} model d={} L={} vocab={}",
             engine.manifest().preset,
             engine.manifest().model.d_model,
             engine.manifest().model.n_layers,
             engine.manifest().model.vocab_size);

    // OSP = Muon optimizer + SSNorm + EmbProj (the paper's recipe).
    let mut cfg = TrainConfig::from_args(&args);
    cfg.optimizer = "muon".into();
    cfg.arch = "ssnorm_embproj".into();
    cfg.steps = args.u64_or("steps", 10);
    cfg.eval_every = 0;
    cfg.run_dir = "".into(); // no telemetry for the quickstart

    let mut trainer = Trainer::new(engine, cfg)?;
    for step in 0..trainer.cfg.steps {
        let (loss, kurt) = trainer.step(step)?;
        let kmax = kurt.iter().cloned().fold(f32::MIN, f32::max);
        println!("step {step:3}  loss {loss:.4}  residual kurt_max {kmax:+.3}");
    }
    let (ppl, _) = trainer.evaluate()?;
    println!("held-out perplexity after {} steps: {ppl:.2}",
             trainer.cfg.steps);
    Ok(())
}
