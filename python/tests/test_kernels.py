"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/values; assert_allclose against ref.py is THE
core correctness signal for the kernels that end up inside the shipped
HLO artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import fake_quant
from compile.kernels.hadamard import hadamard
from compile.kernels.newton_schulz import matmul_pallas, ns_orthogonalize
from compile.kernels.ssnorm import ssnorm

SETTINGS = dict(deadline=None, max_examples=15)


def _randn(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     jnp.float32)


# ---------------------------------------------------------------- matmul

@settings(**SETTINGS)
@given(m=st.integers(1, 160), k=st.integers(1, 160), n=st.integers(1, 160),
       seed=st.integers(0, 2**30))
def test_matmul_matches_ref(m, k, n, seed):
    a = _randn(seed, (m, k))
    b = _randn(seed + 1, (k, n))
    np.testing.assert_allclose(matmul_pallas(a, b), ref.matmul_ref(a, b),
                               rtol=2e-5, atol=2e-5)


def test_matmul_tile_aligned():
    a = _randn(0, (256, 128))
    b = _randn(1, (128, 256))
    np.testing.assert_allclose(matmul_pallas(a, b), ref.matmul_ref(a, b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- newton-schulz

@settings(**SETTINGS)
@given(m=st.sampled_from([8, 24, 64, 96]), n=st.sampled_from([8, 32, 64]),
       seed=st.integers(0, 2**30))
def test_ns_matches_ref(m, n, seed):
    g = _randn(seed, (m, n))
    np.testing.assert_allclose(ns_orthogonalize(g),
                               ref.ns_orthogonalize_ref(g),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**30))
def test_ns_output_near_orthogonal(seed):
    """NS output should have singular values near 1: X^T X ~ I for a
    well-conditioned tall input (the paper's UV^T map, Eq. 2)."""
    g = _randn(seed, (96, 48))
    x = np.asarray(ref.ns_orthogonalize_ref(g, steps=10))
    gram = x.T @ x
    # Quintic NS converges to sigma in [0.7, 1.3]; with 10 steps and a
    # random Gaussian (well-conditioned whp) we get close to identity.
    assert np.abs(np.diag(gram) - 1.0).max() < 0.35
    off = gram - np.diag(np.diag(gram))
    assert np.abs(off).max() < 0.35


def test_ns_matches_svd_oracle():
    """Against the true polar factor U V^T computed by numpy SVD."""
    g = np.asarray(_randn(7, (64, 32)))
    u, _s, vt = np.linalg.svd(g, full_matrices=False)
    polar = u @ vt
    x = np.asarray(ref.ns_orthogonalize_ref(jnp.asarray(g), steps=10))
    # NS(5-step quintic) is an approximation; direction must match well.
    cos = np.sum(polar * x) / (np.linalg.norm(polar) * np.linalg.norm(x))
    assert cos > 0.98, cos


@settings(deadline=None, max_examples=6)
@given(m=st.sampled_from([16, 48, 64]), n=st.sampled_from([16, 64]),
       seed=st.integers(0, 2**30))
def test_polar_is_orthogonal(m, n, seed):
    """The cubic polar iteration must reach true orthogonality (used for
    EmbProj init and rotation matrices, unlike Muon's quintic)."""
    g = _randn(seed, (m, n))
    x = np.asarray(ref.polar_ref(g, steps=40))
    if m >= n:
        gram = x.T @ x
    else:
        gram = x @ x.T
    assert np.abs(gram - np.eye(min(m, n))).max() < 1e-3


def test_ns_transposed_consistency():
    g = _randn(3, (40, 80))
    a = ref.ns_orthogonalize_ref(g)
    b = ref.ns_orthogonalize_ref(g.T).T
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- ssnorm

@settings(**SETTINGS)
@given(rows=st.integers(1, 200), d=st.integers(2, 256),
       gamma=st.floats(0.1, 30.0), seed=st.integers(0, 2**30))
def test_ssnorm_matches_ref(rows, d, gamma, seed):
    x = _randn(seed, (rows, d), scale=3.0)
    np.testing.assert_allclose(ssnorm(x, jnp.float32(gamma)),
                               ref.ssnorm_ref(x, gamma),
                               rtol=2e-5, atol=2e-5)


def test_ssnorm_output_norm_is_gamma():
    """||SSNorm(x)||_2 == gamma for every row — the single-scale property
    that removes the privileged per-channel basis (paper Eq. 3)."""
    x = _randn(0, (32, 64), scale=5.0)
    y = np.asarray(ref.ssnorm_ref(x, 4.0))
    np.testing.assert_allclose(np.linalg.norm(y, axis=-1), 4.0, rtol=1e-4)


def test_ssnorm_scale_invariance():
    """SSNorm(c*x) == SSNorm(x): magnitude information is fully removed."""
    x = _randn(1, (8, 32))
    np.testing.assert_allclose(ref.ssnorm_ref(3.7 * x, 2.0),
                               ref.ssnorm_ref(x, 2.0), rtol=1e-4, atol=1e-5)


def test_ssnorm_3d_input():
    x = _randn(2, (2, 16, 48))
    np.testing.assert_allclose(ssnorm(x, jnp.float32(6.0)),
                               ref.ssnorm_ref(x, 6.0), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- fake_quant

@settings(**SETTINGS)
@given(rows=st.integers(1, 100), d=st.integers(1, 128),
       bits=st.integers(2, 8), seed=st.integers(0, 2**30))
def test_fake_quant_matches_ref(rows, d, bits, seed):
    x = _randn(seed, (rows, d), scale=4.0)
    levels = float(2 ** (bits - 1) - 1)
    np.testing.assert_allclose(fake_quant(x, levels),
                               ref.fake_quant_ref(x, levels),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2**30))
def test_fake_quant_error_bound(bits, seed):
    """|x - q(x)| <= scale/2 + eps where scale = absmax/levels (RTN)."""
    x = _randn(seed, (16, 64), scale=2.0)
    levels = float(2 ** (bits - 1) - 1)
    q = np.asarray(ref.fake_quant_ref(x, levels))
    scale = np.abs(np.asarray(x)).max(-1, keepdims=True) / levels
    assert (np.abs(q - np.asarray(x)) <= scale / 2 + 1e-5).all()


def test_fake_quant_identity_at_high_levels():
    """levels = 2**20 must be numerically the identity — this is how the
    16-bit columns of Table 2 are expressed at runtime."""
    x = _randn(0, (8, 32))
    q = ref.fake_quant_ref(x, float(2 ** 20))
    np.testing.assert_allclose(q, x, rtol=1e-4, atol=1e-5)


def test_fake_quant_grid_size():
    """4-bit RTN must produce at most 16 distinct values per row."""
    x = _randn(1, (4, 256), scale=3.0)
    q = np.asarray(ref.fake_quant_ref(x, 7.0))
    for row in q:
        assert len(np.unique(np.round(row / (np.abs(row).max() / 7 + 1e-8))
                             )) <= 16


# --------------------------------------------------------------- hadamard

@settings(**SETTINGS)
@given(rows=st.integers(1, 64),
       n=st.sampled_from([2, 8, 16, 64, 128, 176, 352, 96]),
       seed=st.integers(0, 2**30))
def test_hadamard_matches_ref(rows, n, seed):
    x = _randn(seed, (rows, n))
    np.testing.assert_allclose(hadamard(x), ref.hadamard_ref(x),
                               rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(n=st.sampled_from([16, 64, 176, 352]), seed=st.integers(0, 2**30))
def test_hadamard_involution(n, seed):
    x = _randn(seed, (8, n))
    np.testing.assert_allclose(ref.hadamard_ref(ref.hadamard_ref(x)), x,
                               rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(n=st.sampled_from([16, 64, 176]), seed=st.integers(0, 2**30))
def test_hadamard_preserves_norm(n, seed):
    """Orthogonality: per-row L2 norm is preserved."""
    x = _randn(seed, (8, n))
    y = np.asarray(ref.hadamard_ref(x))
    np.testing.assert_allclose(np.linalg.norm(y, axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)


def test_hadamard_flattens_outliers():
    """The rotation's whole point: a one-hot spike becomes flat."""
    x = np.zeros((1, 64), np.float32)
    x[0, 17] = 64.0
    y = np.asarray(ref.hadamard_ref(jnp.asarray(x)))
    assert np.abs(y).max() <= 64.0 / np.sqrt(64) + 1e-4


# ------------------------------------------------------------- kurtosis

def test_excess_kurtosis_gaussian_near_zero():
    x = _randn(0, (200_000,))
    k = float(ref.excess_kurtosis_ref(x))
    assert abs(k) < 0.1, k


def test_excess_kurtosis_heavy_tail_positive():
    x = np.asarray(_randn(1, (100_000,))).copy()
    x[:50] *= 100.0  # inject outliers
    assert float(ref.excess_kurtosis_ref(jnp.asarray(x))) > 50.0


def test_excess_kurtosis_uniform_negative():
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, 100_000)
                    .astype(np.float32))
    k = float(ref.excess_kurtosis_ref(x))
    assert -1.4 < k < -1.0  # uniform has excess kurtosis -1.2
