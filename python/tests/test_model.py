"""L2 model invariants: shapes, OSP component semantics, quantization
taps, and the EmbProj absorption (computational invariance, Section 3.3).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import PRESETS
from compile.model import QuantTaps

CFG = PRESETS["tiny"]
KEY = jax.random.PRNGKey(0)


def _toks(cfg, batch=2, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, cfg.seq_len),
                              0, cfg.vocab_size)


def _taps(a_bits=16, kv_bits=16, had=0.0, use_pallas=False):
    lv = lambda b: float(2 ** 20 if b >= 16 else 2 ** (b - 1) - 1)
    return QuantTaps(jnp.float32(lv(a_bits)), jnp.float32(lv(kv_bits)),
                     jnp.float32(had), use_pallas=use_pallas)


ARCHS = [dict(norm="rms", embproj=False), dict(norm="ss", embproj=False),
         dict(norm="rms", embproj=True), dict(norm="ss", embproj=True)]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = CFG.with_(**arch)
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    logits, aux = model.forward(params, toks, cfg)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab_size)
    assert aux["kurt"].shape == (2 * cfg.n_layers,)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_param_flatten_roundtrip(arch):
    cfg = CFG.with_(**arch)
    params = model.init_params(cfg, KEY)
    flat = model.flatten_params(cfg, params)
    back = model.unflatten_params(cfg, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(params[k], back[k])


def test_param_specs_embproj_presence():
    assert not any(s.name.startswith("embproj")
                   for s in model.param_specs(CFG))
    cfg = CFG.with_(embproj=True)
    names = [s.name for s in model.param_specs(cfg)]
    assert "embproj_in" in names and "embproj_out" in names


def test_embproj_orthogonal_init():
    """EmbProj must start ~orthogonal to preserve embedding norms."""
    cfg = CFG.with_(embproj=True)
    params = model.init_params(cfg, KEY)
    p = np.asarray(params["embproj_in"])
    gram = p.T @ p
    assert np.abs(gram - np.eye(cfg.d_model)).max() < 0.05


def test_ssnorm_param_is_scalar():
    cfg = CFG.with_(norm="ss")
    specs = {s.name: s for s in model.param_specs(cfg)}
    assert specs["layers.0.attn_norm"].shape == (1,)
    assert specs["final_norm"].shape == (1,)
    # initialized to sqrt(d) so t=0 matches unit-scale RMSNorm
    params = model.init_params(cfg, KEY)
    np.testing.assert_allclose(params["final_norm"][0],
                               np.sqrt(cfg.d_model), rtol=1e-6)


def test_quant_taps_off_is_identity():
    """levels=2**20 + had=0 must match the un-tapped forward closely."""
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    base, _ = model.forward(params, toks, cfg)
    tapped, _ = model.forward(params, toks, cfg, taps=_taps(16, 16, 0.0))
    np.testing.assert_allclose(base, tapped, rtol=1e-3, atol=1e-3)


def test_quant_4bit_changes_logits():
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    base, _ = model.forward(params, toks, cfg)
    q, _ = model.forward(params, toks, cfg, taps=_taps(4, 4, 0.0))
    assert np.abs(np.asarray(base) - np.asarray(q)).max() > 1e-3


def test_quant_pallas_matches_jnp_taps():
    """The pallas-kernel taps and the jnp-oracle taps must agree — this is
    the cross-flavor guarantee the artifact build relies on."""
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    a, _ = model.forward(params, toks, cfg,
                         taps=_taps(4, 8, 1.0, use_pallas=False))
    b, _ = model.forward(params, toks, cfg,
                         taps=_taps(4, 8, 1.0, use_pallas=True))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_embproj_absorption_invariance():
    """Folding embproj_in into embed and embproj_out into unembed must
    reproduce the plain architecture's logits exactly (Section 3.3:
    'absorbed into their adjacent embeddings after training')."""
    cfg = CFG.with_(embproj=True)
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    ref_logits, _ = model.forward(params, toks, cfg)

    plain_cfg = CFG.with_(embproj=False)
    absorbed = {k: v for k, v in params.items()
                if not k.startswith("embproj")}
    absorbed["embed"] = params["embed"] @ params["embproj_in"]
    absorbed["unembed"] = params["embproj_out"] @ params["unembed"]
    got, _ = model.forward(absorbed, toks, plain_cfg)
    np.testing.assert_allclose(ref_logits, got, rtol=2e-4, atol=2e-4)


def test_loss_decreases_with_training_signal():
    """Sanity: loss at init is ~ln(V) for uniform predictions."""
    cfg = CFG
    params = model.init_params(cfg, KEY)
    loss, _ = model.loss_fn(params, _toks(cfg), cfg)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


def test_nll_count():
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg, batch=3)
    s, count, kurt = model.nll(params, toks, cfg)
    assert int(count) == 3 * (cfg.seq_len - 1)
    assert float(s) > 0
    assert kurt.shape == (2 * cfg.n_layers,)


def test_causality():
    """Changing a future token must not affect past logits."""
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = np.asarray(_toks(cfg))
    logits1, _ = model.forward(params, jnp.asarray(toks), cfg)
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % cfg.vocab_size
    logits2, _ = model.forward(params, jnp.asarray(toks2), cfg)
    np.testing.assert_allclose(logits1[:, :-1], logits2[:, :-1],
                               rtol=1e-5, atol=1e-5)


def test_kurtosis_tap_detects_planted_outlier():
    """Scaling one channel of the embedding matrix must raise the
    measured residual-stream kurtosis — the Fig-2/3 measurement works."""
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    _, aux0 = model.forward(params, toks, cfg)
    spiked = dict(params)
    col = np.asarray(params["embed"]).copy()
    col[:, 3] *= 50.0
    spiked["embed"] = jnp.asarray(col)
    _, aux1 = model.forward(spiked, toks, cfg)
    assert float(aux1["kurt"][0]) > float(aux0["kurt"][0]) + 5.0


def test_probe_outputs():
    cfg = CFG
    params = model.init_params(cfg, KEY)
    toks = _toks(cfg)
    _, aux = model.forward(params, toks, cfg, probe_layers=[0, 1])
    pr = aux["probes"]
    assert pr["mhsa_in"].shape == (2, 2, cfg.seq_len, cfg.d_model)
    assert pr["attn_logits"].shape == (
        2, 2, cfg.n_heads, cfg.seq_len, cfg.seq_len)
    assert pr["q_mag"].shape == (2, 2, cfg.n_heads, cfg.head_dim)
