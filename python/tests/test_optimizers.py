"""Optimizer unit tests: update semantics, state-spec completeness, and
the Muon-vs-Adam structural difference the whole paper rests on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, optimizers
from compile.config import PRESETS
from compile.optimizers import (ADAM_B1, ADAM_B2, ADAM_EPS, OPTIMIZERS,
                                _adam_leaf, _inv_fourth_root, _muon_update)

CFG = PRESETS["tiny"]
KEY = jax.random.PRNGKey(0)


def _grads(cfg, params, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed),
                              (2, cfg.seq_len), 0, cfg.vocab_size)
    return jax.grad(lambda p: model.loss_fn(p, toks, cfg)[0])(params), toks


@pytest.mark.parametrize("opt", OPTIMIZERS)
def test_state_specs_cover_all_params(opt):
    """Every parameter must be handled by exactly one branch: element-wise
    Adam state or a matrix-preconditioner state."""
    cfg = CFG.with_(norm="ss", embproj=True)
    specs = optimizers.opt_state_specs(opt, cfg)
    names = {n for n, _s, _i in specs}
    assert "step" in names
    for s in model.param_specs(cfg):
        adam = f"adam_m.{s.name}" in names
        matrix = any(n.endswith(f".{s.name}") and not n.startswith("adam")
                     for n in names)
        if opt == "adam":
            assert adam and not matrix, s.name
        elif s.kind == "norm":
            assert adam and not matrix, s.name
        elif opt in ("muon", "shampoo", "soap") and s.kind in ("embed",
                                                               "unembed"):
            assert adam, s.name  # decoupled embedding optimization (§3.3)
        elif s.kind == "matrix":
            assert matrix and not adam, (opt, s.name)


def test_muon_noadam_puts_embeddings_on_muon():
    cfg = CFG
    specs = {n for n, _s, _i in optimizers.opt_state_specs("muon_noadam",
                                                           cfg)}
    assert "muon_buf.embed" in specs and "muon_buf.unembed" in specs
    assert "adam_m.embed" not in specs


@pytest.mark.parametrize("opt", OPTIMIZERS)
def test_update_step_runs_and_descends(opt):
    cfg = CFG
    params = model.init_params(cfg, KEY)
    grads, toks = _grads(cfg, params)
    state = optimizers.init_opt_state(opt, cfg)
    l0, _ = model.loss_fn(params, toks, cfg)
    p, s = params, state
    for _ in range(3):
        grads = jax.grad(lambda q: model.loss_fn(q, toks, cfg)[0])(p)
        p, s = optimizers.opt_update(opt, cfg, p, grads, s, 3e-4,
                                     use_pallas=False)
    l1, _ = model.loss_fn(p, toks, cfg)
    assert float(l1) < float(l0), (opt, float(l0), float(l1))
    assert float(s["step"][0]) == 3.0


def test_adam_leaf_matches_manual():
    p = jnp.asarray([1.0, -2.0])
    g = jnp.asarray([0.5, 0.1])
    m0 = jnp.zeros(2)
    v0 = jnp.zeros(2)
    p1, m1, v1 = _adam_leaf(p, g, m0, v0, lr=0.1, t=1.0, wd=0.0)
    m_exp = (1 - ADAM_B1) * np.asarray(g)
    v_exp = (1 - ADAM_B2) * np.asarray(g) ** 2
    mhat = m_exp / (1 - ADAM_B1)
    vhat = v_exp / (1 - ADAM_B2)
    p_exp = np.asarray(p) - 0.1 * mhat / (np.sqrt(vhat) + ADAM_EPS)
    np.testing.assert_allclose(p1, p_exp, rtol=1e-6)
    np.testing.assert_allclose(m1, m_exp, rtol=1e-6)
    np.testing.assert_allclose(v1, v_exp, rtol=1e-6)


def test_adam_is_diagonal_muon_is_not():
    """The paper's core mechanism, stated structurally: Muon's update is
    *equivariant under rotations* of the gradient (no privileged basis):
    update(Q g) == Q update(g) for orthogonal Q. Adam's element-wise
    preconditioner breaks this — its update is tied to the coordinate
    axes, which is exactly what breeds outlier channels."""
    from compile.kernels import ref as kref

    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (16, 16))
    q = kref.polar_ref(jax.random.normal(jax.random.PRNGKey(1), (16, 16)),
                       steps=40)

    # Muon (momentum=0 path): equivariance holds.
    u_g, _ = _muon_update(g, jnp.zeros_like(g), use_pallas=False)
    u_qg, _ = _muon_update(q @ g, jnp.zeros_like(g), use_pallas=False)
    np.testing.assert_allclose(np.asarray(u_qg), np.asarray(q @ u_g),
                               rtol=5e-2, atol=5e-2)

    # Adam (one step from zero state): NOT equivariant — sign(Q g) != Q
    # sign(g). Measure the violation and require it to be large.
    def adam_u(grad):
        p1, _m, _v = _adam_leaf(jnp.zeros_like(grad), grad,
                                jnp.zeros_like(grad), jnp.zeros_like(grad),
                                lr=1.0, t=1.0, wd=0.0)
        return -np.asarray(p1)  # the update direction

    viol = np.abs(adam_u(q @ g) - np.asarray(q) @ adam_u(g)).max()
    assert viol > 0.5, viol


def test_muon_update_is_near_orthogonal():
    g = jax.random.normal(KEY, (32, 32))
    u, _ = _muon_update(g, jnp.zeros((32, 32)), use_pallas=False)
    gram = np.asarray(u).T @ np.asarray(u)
    d = np.diag(gram)
    assert (d > 0.4).all() and (d < 1.7).all()


def test_muon_momentum_accumulates():
    g = jnp.ones((4, 4))
    _u1, buf1 = _muon_update(g, jnp.zeros((4, 4)), use_pallas=False)
    _u2, buf2 = _muon_update(g, buf1, use_pallas=False)
    assert float(jnp.abs(buf2).sum()) > float(jnp.abs(buf1).sum())


def test_inv_fourth_root_identity():
    eye = jnp.eye(16)
    r = _inv_fourth_root(eye, iters=12)
    np.testing.assert_allclose(np.asarray(r), np.eye(16), atol=0.05)


def test_inv_fourth_root_diagonal():
    d = jnp.diag(jnp.asarray([1.0, 4.0, 16.0, 0.25]))
    r = np.asarray(_inv_fourth_root(d, iters=20))
    expected = np.diag([1.0, 4.0 ** -0.25, 16.0 ** -0.25, 0.25 ** -0.25])
    np.testing.assert_allclose(r, expected, atol=0.08)


def test_weight_decay_shrinks_params_without_grad():
    cfg = CFG
    params = model.init_params(cfg, KEY)
    zero_grads = {k: jnp.zeros_like(v) for k, v in params.items()}
    state = optimizers.init_opt_state("adam", cfg)
    p2, _ = optimizers.opt_update("adam", cfg, params, zero_grads, state,
                                  0.1, use_pallas=False)
    w0 = np.abs(np.asarray(params["layers.0.wq"])).sum()
    w1 = np.abs(np.asarray(p2["layers.0.wq"])).sum()
    assert w1 < w0  # decoupled wd applied
    # norm params exempt from decay
    np.testing.assert_allclose(p2["final_norm"], params["final_norm"])


def test_opt_state_init_kinds():
    cfg = CFG
    st = optimizers.init_opt_state("soap", cfg)
    q = np.asarray(st["so_ql.layers.0.wq"])
    np.testing.assert_array_equal(q, np.eye(q.shape[0]))
    assert (np.asarray(st["so_m.layers.0.wq"]) == 0).all()
