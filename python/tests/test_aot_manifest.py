"""AOT build integrity: artifact catalogue completeness and manifest
consistency. Uses the builder in-memory (no lowering) plus one real
lowering smoke test on the cheapest artifact."""

import json
from pathlib import Path

import jax
import pytest

from compile import aot, optimizers
from compile.aot import ARCHS, GRAD_ARCHS, TRAIN_MATRIX, ArtifactBuilder
from compile.config import PRESETS
from compile.model import param_specs


@pytest.fixture(scope="module")
def builder():
    cfg = PRESETS["tiny"]
    b = ArtifactBuilder(cfg, Path("/tmp/osp_aot_test"), use_pallas=False)
    b.build_all()
    return b


def test_catalogue_complete(builder):
    names = set(builder.entries)
    for arch in ARCHS:
        for prefix in ("init", "evalq", "logitsq", "probe"):
            assert f"{prefix}_{arch}" in names
    for arch in GRAD_ARCHS:
        assert f"grad_{arch}" in names
    for opt, arch in TRAIN_MATRIX:
        assert f"train_{opt}_{arch}" in names
    assert any(n.startswith("ns_") for n in names)


def test_train_io_counts(builder):
    cfg = PRESETS["tiny"]
    for opt, arch in TRAIN_MATRIX:
        acfg = cfg.with_(**ARCHS[arch])
        e = builder.entries[f"train_{opt}_{arch}"]
        np_ = len(param_specs(acfg))
        no = len(optimizers.opt_state_specs(opt, acfg))
        assert len(e["inputs"]) == np_ + no + 2   # + tokens + lr
        assert len(e["outputs"]) == np_ + no + 2  # + loss + kurt


def test_io_metadata_shapes_match_specs(builder):
    """Every input's declared shape must match its ShapeDtypeStruct."""
    for name, e in builder.entries.items():
        for spec, meta in e["inputs"]:
            assert list(spec.shape) == meta["shape"], (name, meta)
            want = "i32" if spec.dtype.name == "int32" else "f32"
            assert meta["dtype"] == want, (name, meta)


def test_ns_artifacts_cover_all_matrix_shapes(builder):
    cfg = PRESETS["tiny"]
    for arch in GRAD_ARCHS:
        acfg = cfg.with_(**ARCHS[arch])
        for s in param_specs(acfg):
            if len(s.shape) == 2 and s.kind in ("matrix", "embed",
                                                "unembed"):
                m, n = s.shape
                assert f"ns_{m}x{n}" in builder.entries, s.name


def test_lowering_smoke_and_hlo_wellformed(builder):
    name = sorted(n for n in builder.entries if n.startswith("ns_"))[0]
    text, _dt = builder.lower(name)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "custom-call" not in text  # CPU PJRT 0.5.1 can't run those


def test_manifest_roundtrip(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--preset", "tiny",
                   "--only", "ns_64x64"])
    assert rc == 0
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["model_config"]["d_model"] == 64
    assert "ns_64x64" in manifest["artifacts"]
    entry = manifest["artifacts"]["ns_64x64"]
    assert (tmp_path / entry["file"]).exists()
    for arch in ARCHS:
        assert manifest["param_specs"][arch]
        assert set(manifest["opt_specs"][arch]) == set(optimizers.OPTIMIZERS)
    # cached second run: same hash, no rebuild needed
    rc = aot.main(["--out-dir", str(tmp_path), "--preset", "tiny",
                   "--only", "ns_64x64"])
    assert rc == 0
