"""L2: LLaMA-style decoder with the OSP architectural knobs (build-time).

Implements the paper's three pre-training interventions as configuration:

  * norm = "rms" | "ss"   — RMSNorm (per-channel scale vector, outlier-
    prone baseline) vs Single-Scale RMSNorm (Eq. 3).
  * embproj = True|False  — learnable full-rank projections after the
    embedding / before the unembedding (Section 3.3), orthogonally
    initialized via Newton-Schulz of a Gaussian.

plus the quantization taps used by the evalq/logitsq artifacts: per-token
RTN fake-quantization of every linear-layer input activation, KV-cache
quantization, and the optional online Hadamard rotation of the FFN hidden
state ("FFN Had"). Bit-widths arrive as *runtime* scalars (levels =
2**(bits-1) - 1), so one lowered artifact serves all bit configurations.

Autodiff note: the training loss path uses the pure-jnp reference kernels
(Pallas interpret-mode calls have no transpose rule), while the forward-
only artifacts (evalq/logitsq/probe) and the optimizer's Newton-Schulz
run the Pallas kernels. test_kernels.py pins the two numerically equal.
"""

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ref
from .kernels.fake_quant import fake_quant
from .kernels.hadamard import hadamard
from .kernels.ssnorm import ssnorm


# --------------------------------------------------------------------------
# Parameter specs: the single source of truth for the flattened parameter
# ordering shared with the Rust side through artifacts/manifest.json.
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    init: str   # "normal" | "normal_out" | "zeros" | "ones" | "sqrt_d" | "orthogonal"
    kind: str   # "matrix" | "embed" | "unembed" | "norm"


def param_specs(cfg: ModelConfig):
    """Ordered parameter list. Order is load-bearing: it defines the
    flattened calling convention of every artifact."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    norm_shape = (1,) if cfg.norm == "ss" else (d,)
    norm_init = "sqrt_d" if cfg.norm == "ss" else "ones"
    specs = [ParamSpec("embed", (v, d), "normal", "embed")]
    if cfg.embproj:
        specs.append(ParamSpec("embproj_in", (d, d), "orthogonal", "matrix"))
        specs.append(ParamSpec("embproj_out", (d, d), "orthogonal", "matrix"))
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs += [
            ParamSpec(p + "attn_norm", norm_shape, norm_init, "norm"),
            ParamSpec(p + "wq", (d, d), "normal", "matrix"),
            ParamSpec(p + "wk", (d, d), "normal", "matrix"),
            ParamSpec(p + "wv", (d, d), "normal", "matrix"),
            ParamSpec(p + "wo", (d, d), "normal_out", "matrix"),
            ParamSpec(p + "ffn_norm", norm_shape, norm_init, "norm"),
            ParamSpec(p + "w_gate", (d, f), "normal", "matrix"),
            ParamSpec(p + "w_up", (d, f), "normal", "matrix"),
            ParamSpec(p + "w_down", (f, d), "normal_out", "matrix"),
        ]
    specs.append(ParamSpec("final_norm", norm_shape, norm_init, "norm"))
    specs.append(ParamSpec("unembed", (d, v), "normal", "unembed"))
    return specs


def init_params(cfg: ModelConfig, key):
    """Initialize the parameter dict. normal_out is scaled down by
    1/sqrt(2*n_layers) (residual-branch init); EmbProj is orthogonalized
    with Newton-Schulz so it starts norm-preserving (Section 3.3)."""
    params = {}
    residual_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        if spec.init == "normal":
            w = cfg.init_std * jax.random.normal(sub, spec.shape, jnp.float32)
        elif spec.init == "normal_out":
            w = cfg.init_std * residual_scale * jax.random.normal(
                sub, spec.shape, jnp.float32)
        elif spec.init == "ones":
            w = jnp.ones(spec.shape, jnp.float32)
        elif spec.init == "sqrt_d":
            w = jnp.full(spec.shape, jnp.sqrt(jnp.float32(cfg.d_model)))
        elif spec.init == "zeros":
            w = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "orthogonal":
            g = jax.random.normal(sub, spec.shape, jnp.float32)
            w = ref.polar_ref(g, steps=40)
        else:
            raise ValueError(spec.init)
        params[spec.name] = w
    return params


def flatten_params(cfg: ModelConfig, params: dict):
    return [params[s.name] for s in param_specs(cfg)]


def unflatten_params(cfg: ModelConfig, flat):
    specs = param_specs(cfg)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {s.name: x for s, x in zip(specs, flat)}


# --------------------------------------------------------------------------
# Quantization taps
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantTaps:
    """Runtime quantization scalars threaded through the forward pass.

    a_levels / kv_levels = 2**(bits-1) - 1 as f32 (pass 2**20 for "off").
    had_flag in {0.0, 1.0}: online Hadamard on the FFN hidden state before
    quantizing it (the matching pre-rotation of w_down happens in Rust).
    use_pallas: route taps through the Pallas kernels (forward-only graphs).
    """
    a_levels: jnp.ndarray
    kv_levels: jnp.ndarray
    had_flag: jnp.ndarray
    use_pallas: bool = True

    def act(self, x):
        return fake_quant(x, self.a_levels, use_pallas=self.use_pallas)

    def kv(self, x):
        return fake_quant(x, self.kv_levels, use_pallas=self.use_pallas)

    def ffn_hidden(self, h):
        rotated = hadamard(h, use_pallas=self.use_pallas)
        h = jnp.where(self.had_flag > 0.5, rotated, h)
        return self.act(h)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _norm(x, w, cfg: ModelConfig, use_pallas: bool):
    if cfg.norm == "ss":
        if use_pallas:
            return ssnorm(x, w[0])
        return ref.ssnorm_ref(x, w[0])
    return ref.rmsnorm_ref(x, w)


def _rope(x, theta: float):
    """Rotary embedding over [B, H, S, hd]."""
    b, h, s, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def forward(params: dict, tokens, cfg: ModelConfig,
            taps: Optional[QuantTaps] = None, probe_layers=None,
            use_pallas_norm: bool = False):
    """Run the decoder. Returns (logits, aux) where aux always contains
    "kurt": excess kurtosis [2*L] of the residual-stream inputs to MHSA
    and FFN per layer (the paper's Fig-2/3 measurement points), and, if
    probe_layers is given, the raw probe tensors for Figs 2/5/6/8-11.
    """
    b, s = tokens.shape
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    probe_layers = probe_layers or []

    x = params["embed"][tokens]  # [B, S, D]
    if cfg.embproj:
        x = x @ params["embproj_in"]

    kurts = []
    probes = {"mhsa_in": [], "ffn_in": [], "q_mag": [], "k_mag": [],
              "attn_logits": []}
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        # ---- MHSA ----
        kurts.append(ref.excess_kurtosis_ref(x))
        if i in probe_layers:
            probes["mhsa_in"].append(x)
        h = _norm(x, params[p + "attn_norm"], cfg, use_pallas_norm)
        if taps is not None:
            h = taps.act(h)
        q = _split_heads(h @ params[p + "wq"], nh)
        k = _split_heads(h @ params[p + "wk"], nh)
        v = _split_heads(h @ params[p + "wv"], nh)
        q = _rope(q, cfg.rope_theta)
        k = _rope(k, cfg.rope_theta)
        if taps is not None:
            k = taps.kv(k)
            v = taps.kv(v)
        logits_att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(hd))
        if i in probe_layers:
            probes["q_mag"].append(jnp.mean(jnp.abs(q), axis=2))   # [B,H,hd]
            probes["k_mag"].append(jnp.mean(jnp.abs(k), axis=2))
            probes["attn_logits"].append(logits_att)
        logits_att = jnp.where(causal[None, None], logits_att, -1e30)
        attn = jax.nn.softmax(logits_att, axis=-1)
        out = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", attn, v))
        if taps is not None:
            out = taps.act(out)
        x = x + out @ params[p + "wo"]

        # ---- FFN (SwiGLU) ----
        kurts.append(ref.excess_kurtosis_ref(x))
        if i in probe_layers:
            probes["ffn_in"].append(x)
        h = _norm(x, params[p + "ffn_norm"], cfg, use_pallas_norm)
        if taps is not None:
            h = taps.act(h)
        g = jax.nn.silu(h @ params[p + "w_gate"]) * (h @ params[p + "w_up"])
        if taps is not None:
            g = taps.ffn_hidden(g)
        x = x + g @ params[p + "w_down"]

    x = _norm(x, params["final_norm"], cfg, use_pallas_norm)
    if cfg.embproj:
        x = x @ params["embproj_out"]
    if taps is not None:
        x = taps.act(x)
    logits = x @ params["unembed"]

    aux = {"kurt": jnp.stack(kurts)}
    if probe_layers:
        aux["probes"] = {k: jnp.stack(vs) for k, vs in probes.items() if vs}
    return logits, aux


def nll(params, tokens, cfg, taps=None):
    """Summed next-token negative log-likelihood + token count + kurt."""
    logits, aux = forward(params, tokens, cfg, taps=taps)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    count = jnp.float32(tgt.size)
    return -jnp.sum(picked), count, aux["kurt"]


def loss_fn(params, tokens, cfg):
    """Mean cross-entropy loss (training path: jnp kernels only)."""
    s, count, kurt = nll(params, tokens, cfg, taps=None)
    return s / count, kurt
