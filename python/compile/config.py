"""Model / architecture configuration shared by the L2 model and aot.py.

The Rust side never sees these dataclasses; aot.py serializes the resolved
config into artifacts/manifest.json and the coordinator is manifest-driven.
"""

from dataclasses import dataclass, asdict, replace


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style decoder configuration.

    norm: "rms"  -> standard RMSNorm with a learnable per-channel scale
                    vector (the outlier-prone baseline),
          "ss"   -> Single-Scale RMSNorm (SSNorm, Eq. 3 of the paper):
                    gamma * x / ||x||_2 with a single learnable scalar.
    embproj: learnable full-rank projections after the embedding and before
             the unembedding (EMBPROJ, Section 3.3). Initialized orthogonal
             (via Newton-Schulz of a Gaussian) to preserve norm statistics.
    """

    vocab_size: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 352  # SwiGLU hidden (~8/3 * d_model, rounded to multiple of 16)
    seq_len: int = 128
    rope_theta: float = 10000.0
    norm: str = "rms"
    embproj: bool = False
    # Quantization taps (evalq artifact): runtime-controlled, see model.py.
    init_std: float = 0.02

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        return asdict(self)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


# Presets. "tiny" lowers fast and is used by pytest and the artifact smoke
# path; "small" is the default experiment scale (see DESIGN.md §2 for the
# scale substitution rationale); "e2e" is the end-to-end driver scale.
PRESETS = {
    "tiny": ModelConfig(vocab_size=256, d_model=64, n_layers=2, n_heads=2,
                        d_ff=176, seq_len=64),
    "small": ModelConfig(vocab_size=512, d_model=128, n_layers=4, n_heads=4,
                         d_ff=352, seq_len=128),
    "e2e": ModelConfig(vocab_size=512, d_model=256, n_layers=6, n_heads=8,
                       d_ff=688, seq_len=128),
}


def arch_name(cfg: ModelConfig) -> str:
    """Canonical architecture tag used in artifact names."""
    return f"{cfg.norm}norm_{'embproj' if cfg.embproj else 'plain'}"
