"""AOT build: lower every executable the Rust coordinator needs to HLO text.

Interchange is HLO *text* (never serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids that the runtime's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

Artifacts (see DESIGN.md §3 for the full table):
  init_<arch>                  (seed)                       -> params...
  train_<opt>_<arch>           (params..., opt..., tokens, lr)
                               -> params'..., opt'..., loss, kurt[2L]
  grad_<arch>                  (params..., tokens)          -> grads..., loss, kurt
  ns_<m>x<n>                   (g)                          -> orth(g)
  evalq_<arch>                 (params..., tokens, a_levels, kv_levels, had)
                               -> nll_sum, count, kurt[2L]
  logitsq_<arch>               (params..., tokens, a_levels, kv_levels, had)
                               -> logits[B,S,V]
  probe_<arch>                 (params..., tokens)          -> probe tensors

plus artifacts/manifest.json describing every input/output tensor, the
parameter/opt-state flattening order, and the model configuration — the
Rust side is entirely manifest-driven.

Caching: each artifact records a content hash (package sources + config +
artifact name); `make artifacts` is a no-op when nothing changed.
"""

import argparse
import hashlib
import json
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import optimizers
from .config import PRESETS, ModelConfig, arch_name
from .kernels.newton_schulz import ns_orthogonalize
from .model import (QuantTaps, forward, init_params, loss_fn, nll,
                    param_specs, unflatten_params)

# Architecture grid used by the experiments (DESIGN.md §5).
ARCHS = {
    "rmsnorm_plain": dict(norm="rms", embproj=False),
    "ssnorm_plain": dict(norm="ss", embproj=False),
    "rmsnorm_embproj": dict(norm="rms", embproj=True),
    "ssnorm_embproj": dict(norm="ss", embproj=True),  # = OSP architecture
}

# (optimizer, arch) pairs that get a fused train artifact (Table 2 rows +
# Table 1 cost rows).
TRAIN_MATRIX = [
    ("adam", "rmsnorm_plain"),
    ("muon_noadam", "rmsnorm_plain"),
    ("muon", "rmsnorm_plain"),
    ("muon", "ssnorm_plain"),
    ("muon", "rmsnorm_embproj"),
    ("muon", "ssnorm_embproj"),
    ("adam", "ssnorm_embproj"),
    ("shampoo", "rmsnorm_plain"),
    ("soap", "rmsnorm_plain"),
]

GRAD_ARCHS = ["rmsnorm_plain", "ssnorm_embproj"]

# Multi-step fused train artifacts (§Perf): K steps per PJRT dispatch via
# lax.scan, amortizing the host<->device parameter round-trip that
# dominates single-step dispatch. Built for the two headline configs.
MULTI_STEP = [("adam", "rmsnorm_plain"), ("muon", "ssnorm_embproj")]
MULTI_K = 8

BATCH_TRAIN = 8
BATCH_EVAL = 8
BATCH_PROBE = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        tuple(shape), jnp.int32 if dtype == "i32" else jnp.float32)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def probe_layer_ids(cfg: ModelConfig):
    ids = sorted({0, cfg.n_layers // 3, (2 * cfg.n_layers) // 3,
                  cfg.n_layers - 1})
    return ids


class ArtifactBuilder:
    """Collects (fn, input specs, io metadata) per artifact and lowers."""

    def __init__(self, cfg: ModelConfig, out_dir: Path, use_pallas: bool):
        self.cfg = cfg
        self.out_dir = out_dir
        self.use_pallas = use_pallas
        self.entries = {}

    # -- builders ---------------------------------------------------------

    def add(self, name, fn, inputs, outputs):
        self.entries[name] = {"fn": fn, "inputs": inputs, "outputs": outputs}

    def param_io(self, cfg, suffix=""):
        return [_io(f"param.{s.name}{suffix}", s.shape)
                for s in param_specs(cfg)]

    def opt_io(self, opt, cfg, suffix=""):
        return [_io(f"opt.{n}{suffix}", shape)
                for n, shape, _init in optimizers.opt_state_specs(opt, cfg)]

    def build_all(self):
        cfg = self.cfg
        for arch, overrides in ARCHS.items():
            acfg = cfg.with_(**overrides)
            self._build_init(arch, acfg)
            self._build_evalq(arch, acfg)
            self._build_logitsq(arch, acfg)
            self._build_probe(arch, acfg)
        for arch in GRAD_ARCHS:
            self._build_grad(arch, cfg.with_(**ARCHS[arch]))
        for opt, arch in TRAIN_MATRIX:
            self._build_train(opt, arch, cfg.with_(**ARCHS[arch]))
        for opt, arch in MULTI_STEP:
            self._build_train_multi(opt, arch, cfg.with_(**ARCHS[arch]),
                                    MULTI_K)
        self._build_ns_shapes()

    def _build_init(self, arch, acfg):
        specs = param_specs(acfg)

        def fn(seed):
            key = jax.random.PRNGKey(seed[0])
            params = init_params(acfg, key)
            return tuple(params[s.name] for s in specs)

        self.add(f"init_{arch}", fn,
                 [( _spec((1,), "i32"), _io("seed", (1,), "i32"))],
                 [_io(f"param.{s.name}", s.shape) for s in specs])

    def _build_train(self, opt, arch, acfg):
        specs = param_specs(acfg)
        ospecs = optimizers.opt_state_specs(opt, acfg)
        np_, no = len(specs), len(ospecs)

        def fn(*args):
            params = {s.name: a for s, a in zip(specs, args[:np_])}
            state = {n: a for (n, _sh, _i), a in
                     zip(ospecs, args[np_:np_ + no])}
            tokens, lr = args[np_ + no], args[np_ + no + 1][0]
            (loss, kurt), grads = jax.value_and_grad(
                lambda p: loss_fn(p, tokens, acfg), has_aux=True)(params)
            new_p, new_s = optimizers.opt_update(
                opt, acfg, params, grads, state, lr,
                use_pallas=self.use_pallas)
            return tuple([new_p[s.name] for s in specs] +
                         [new_s[n] for n, _sh, _i in ospecs] +
                         [loss, kurt])

        inputs = (
            [(_spec(s.shape), _io(f"param.{s.name}", s.shape))
             for s in specs] +
            [(_spec(sh), _io(f"opt.{n}", sh)) for n, sh, _i in ospecs] +
            [(_spec((BATCH_TRAIN, acfg.seq_len), "i32"),
              _io("tokens", (BATCH_TRAIN, acfg.seq_len), "i32")),
             (_spec((1,)), _io("lr", (1,)))])
        outputs = (self.param_io(acfg) + self.opt_io(opt, acfg) +
                   [_io("loss", ()), _io("kurt", (2 * acfg.n_layers,))])
        self.add(f"train_{opt}_{arch}", fn, inputs, outputs)

    def _build_train_multi(self, opt, arch, acfg, k):
        """K fused steps per call via lax.scan (§Perf: amortizes the
        per-dispatch parameter transfer). Same math as k calls of the
        single-step artifact with the same per-step lr."""
        specs = param_specs(acfg)
        ospecs = optimizers.opt_state_specs(opt, acfg)
        np_, no = len(specs), len(ospecs)

        def fn(*args):
            params = {s.name: a for s, a in zip(specs, args[:np_])}
            state = {n: a for (n, _sh, _i), a in
                     zip(ospecs, args[np_:np_ + no])}
            tokens, lrs = args[np_ + no], args[np_ + no + 1]

            def body(carry, xs):
                params, state = carry
                toks, lr = xs
                (loss, kurt), grads = jax.value_and_grad(
                    lambda p: loss_fn(p, toks, acfg), has_aux=True)(params)
                new_p, new_s = optimizers.opt_update(
                    opt, acfg, params, grads, state, lr,
                    use_pallas=self.use_pallas)
                return (new_p, new_s), (loss, kurt)

            (params, state), (losses, kurts) = jax.lax.scan(
                body, (params, state), (tokens, lrs))
            return tuple([params[s.name] for s in specs] +
                         [state[n] for n, _sh, _i in ospecs] +
                         [jnp.mean(losses), kurts[-1]])

        inputs = (
            [(_spec(s.shape), _io(f"param.{s.name}", s.shape))
             for s in specs] +
            [(_spec(sh), _io(f"opt.{n}", sh)) for n, sh, _i in ospecs] +
            [(_spec((k, BATCH_TRAIN, acfg.seq_len), "i32"),
              _io("tokens", (k, BATCH_TRAIN, acfg.seq_len), "i32")),
             (_spec((k,)), _io("lrs", (k,)))])
        outputs = (self.param_io(acfg) + self.opt_io(opt, acfg) +
                   [_io("loss", ()), _io("kurt", (2 * acfg.n_layers,))])
        self.add(f"train{k}_{opt}_{arch}", fn, inputs, outputs)

    def _build_grad(self, arch, acfg):
        specs = param_specs(acfg)

        def fn(*args):
            params = {s.name: a for s, a in zip(specs, args[:len(specs)])}
            tokens = args[len(specs)]
            (loss, kurt), grads = jax.value_and_grad(
                lambda p: loss_fn(p, tokens, acfg), has_aux=True)(params)
            return tuple([grads[s.name] for s in specs] + [loss, kurt])

        inputs = ([(_spec(s.shape), _io(f"param.{s.name}", s.shape))
                   for s in specs] +
                  [(_spec((BATCH_TRAIN, acfg.seq_len), "i32"),
                    _io("tokens", (BATCH_TRAIN, acfg.seq_len), "i32"))])
        outputs = ([_io(f"grad.{s.name}", s.shape) for s in specs] +
                   [_io("loss", ()), _io("kurt", (2 * acfg.n_layers,))])
        self.add(f"grad_{arch}", fn, inputs, outputs)

    def _quant_inputs(self, acfg, batch):
        return [
            (_spec((batch, acfg.seq_len), "i32"),
             _io("tokens", (batch, acfg.seq_len), "i32")),
            (_spec((1,)), _io("a_levels", (1,))),
            (_spec((1,)), _io("kv_levels", (1,))),
            (_spec((1,)), _io("had_flag", (1,))),
        ]

    def _build_evalq(self, arch, acfg):
        specs = param_specs(acfg)

        def fn(*args):
            params = {s.name: a for s, a in zip(specs, args[:len(specs)])}
            tokens, a_lv, kv_lv, had = args[len(specs):len(specs) + 4]
            taps = QuantTaps(a_lv[0], kv_lv[0], had[0],
                             use_pallas=self.use_pallas)
            nll_sum, count, kurt = nll(params, tokens, acfg, taps=taps)
            return (nll_sum, count, kurt)

        inputs = ([(_spec(s.shape), _io(f"param.{s.name}", s.shape))
                   for s in specs] + self._quant_inputs(acfg, BATCH_EVAL))
        outputs = [_io("nll_sum", ()), _io("count", ()),
                   _io("kurt", (2 * acfg.n_layers,))]
        self.add(f"evalq_{arch}", fn, inputs, outputs)

    def _build_logitsq(self, arch, acfg):
        specs = param_specs(acfg)

        def fn(*args):
            params = {s.name: a for s, a in zip(specs, args[:len(specs)])}
            tokens, a_lv, kv_lv, had = args[len(specs):len(specs) + 4]
            taps = QuantTaps(a_lv[0], kv_lv[0], had[0],
                             use_pallas=self.use_pallas)
            logits, _aux = forward(params, tokens, acfg, taps=taps)
            return (logits,)

        inputs = ([(_spec(s.shape), _io(f"param.{s.name}", s.shape))
                   for s in specs] + self._quant_inputs(acfg, BATCH_EVAL))
        outputs = [_io("logits",
                       (BATCH_EVAL, acfg.seq_len, acfg.vocab_size))]
        self.add(f"logitsq_{arch}", fn, inputs, outputs)

    def _build_probe(self, arch, acfg):
        specs = param_specs(acfg)
        pl_ids = probe_layer_ids(acfg)
        b, s = BATCH_PROBE, acfg.seq_len
        d, nh, hd = acfg.d_model, acfg.n_heads, acfg.head_dim
        npl = len(pl_ids)

        def fn(*args):
            params = {sp.name: a for sp, a in zip(specs, args[:len(specs)])}
            tokens = args[len(specs)]
            _logits, aux = forward(params, tokens, acfg,
                                   probe_layers=pl_ids)
            pr = aux["probes"]
            return (aux["kurt"], pr["mhsa_in"], pr["ffn_in"], pr["q_mag"],
                    pr["k_mag"], pr["attn_logits"])

        inputs = ([(_spec(sp.shape), _io(f"param.{sp.name}", sp.shape))
                   for sp in specs] +
                  [(_spec((b, s), "i32"), _io("tokens", (b, s), "i32"))])
        outputs = [
            _io("kurt", (2 * acfg.n_layers,)),
            _io("mhsa_in", (npl, b, s, d)),
            _io("ffn_in", (npl, b, s, d)),
            _io("q_mag", (npl, b, nh, hd)),
            _io("k_mag", (npl, b, nh, hd)),
            _io("attn_logits", (npl, b, nh, s, s)),
        ]
        self.add(f"probe_{arch}", fn, inputs, outputs)

    def _build_ns_shapes(self):
        """One ns_<m>x<n> artifact per distinct matrix shape (used by the
        disaggregated optimizer-parallel mode)."""
        shapes = set()
        for arch in GRAD_ARCHS:
            acfg = self.cfg.with_(**ARCHS[arch])
            for s in param_specs(acfg):
                if s.kind == "matrix" or s.kind in ("embed", "unembed"):
                    if len(s.shape) == 2:
                        shapes.add(s.shape)
        for (m, n) in sorted(shapes):
            def fn(g, _m=m, _n=n):
                return (ns_orthogonalize(g, use_pallas=self.use_pallas),)
            self.add(f"ns_{m}x{n}", fn,
                     [(_spec((m, n)), _io("g", (m, n)))],
                     [_io("orth", (m, n))])

    # -- lowering ---------------------------------------------------------

    def lower(self, name):
        e = self.entries[name]
        specs = [s for s, _meta in e["inputs"]]
        t0 = time.time()
        # keep_unused: the manifest's calling convention is positional, so
        # arguments that an artifact happens not to use (e.g. the unembed
        # matrix in probe_*) must still be real HLO parameters.
        lowered = jax.jit(e["fn"], keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        dt = time.time() - t0
        return text, dt


def _source_hash(cfg: ModelConfig, use_pallas: bool, name: str) -> str:
    h = hashlib.sha256()
    pkg = Path(__file__).parent
    for p in sorted(pkg.rglob("*.py")):
        h.update(p.read_bytes())
    h.update(repr(cfg.to_dict()).encode())
    h.update(str(use_pallas).encode())
    h.update(name.encode())
    h.update(jax.__version__.encode())
    return h.hexdigest()[:16]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default=os.environ.get("OSP_PRESET", "small"),
                    choices=sorted(PRESETS))
    ap.add_argument("--kernels",
                    default=os.environ.get("OSP_KERNELS", "pallas"),
                    choices=["pallas", "jnp"])
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    use_pallas = args.kernels == "pallas"
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    old = {}
    if manifest_path.exists():
        try:
            old = json.loads(manifest_path.read_text()).get("artifacts", {})
        except Exception:
            old = {}

    builder = ArtifactBuilder(cfg, out_dir, use_pallas)
    builder.build_all()

    manifest = {
        "version": 1,
        "preset": args.preset,
        "kernels": args.kernels,
        "model_config": cfg.to_dict(),
        "batch_train": BATCH_TRAIN,
        "batch_eval": BATCH_EVAL,
        "batch_probe": BATCH_PROBE,
        "probe_layers": probe_layer_ids(cfg),
        "archs": {a: dict(ov) for a, ov in ARCHS.items()},
        "param_specs": {},
        "opt_specs": {},
        "artifacts": {},
    }
    for arch, overrides in ARCHS.items():
        acfg = cfg.with_(**overrides)
        manifest["param_specs"][arch] = [
            {"name": s.name, "shape": list(s.shape), "init": s.init,
             "kind": s.kind} for s in param_specs(acfg)]
        manifest["opt_specs"][arch] = {
            opt: [{"name": n, "shape": list(sh), "init": init}
                  for n, sh, init in optimizers.opt_state_specs(opt, acfg)]
            for opt in optimizers.OPTIMIZERS}

    n_built = n_cached = 0
    for name, e in builder.entries.items():
        if args.only and args.only not in name:
            continue
        fname = f"{name}.hlo.txt"
        hsh = _source_hash(cfg, use_pallas, name)
        entry = {
            "file": fname,
            "hash": hsh,
            "inputs": [meta for _s, meta in e["inputs"]],
            "outputs": e["outputs"],
        }
        cached = (not args.force and old.get(name, {}).get("hash") == hsh
                  and (out_dir / fname).exists())
        if cached:
            n_cached += 1
        else:
            text, dt = builder.lower(name)
            (out_dir / fname).write_text(text)
            n_built += 1
            print(f"  lowered {name:32s} {len(text)/1e6:7.2f} MB "
                  f"in {dt:6.1f}s", flush=True)
        manifest["artifacts"][name] = entry

    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"artifacts: {n_built} built, {n_cached} cached -> {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
