"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematical definition; the Pallas kernels in
this package must match these to float tolerance (pytest + hypothesis
enforce it). The oracles are also usable directly in the L2 model when
building the `--kernels jnp` artifact flavor (see DESIGN.md §7).
"""

import jax.numpy as jnp

# Quintic Newton-Schulz coefficients from Jordan et al. (2024) — tuned so
# the iteration maps singular values into ~[0.7, 1.3] within 5 steps.
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5


def matmul_ref(a, b):
    """Plain f32 matmul, the oracle for the tiled Pallas GEMM."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def ns_orthogonalize_ref(g, steps=NS_STEPS, coeffs=NS_COEFFS, eps=1e-7):
    """Muon's Newton-Schulz orthogonalization: G = U S V^T -> ~ U V^T.

    Iterates X <- a X + (b (X X^T) + c (X X^T)^2) X on the Frobenius-
    normalized matrix. Matches Eq. 2 of the paper. Works on any m x n
    matrix; transposes internally so the Gram matrix is the smaller side.
    """
    a, b, c = coeffs
    x = g.astype(jnp.float32)
    transposed = x.shape[0] > x.shape[1]
    if transposed:
        x = x.T
    x = x / (jnp.sqrt(jnp.sum(x * x)) + eps)
    for _ in range(steps):
        gram = x @ x.T
        poly = b * gram + c * (gram @ gram)
        x = a * x + poly @ x
    if transposed:
        x = x.T
    return x


def polar_ref(g, steps=30, eps=1e-7):
    """Exact-limit polar factor via the *cubic* Newton-Schulz iteration
    X <- 1.5 X - 0.5 X X^T X (converges to U V^T for sigma in (0, sqrt 3)).

    The quintic iteration above is tuned for Muon's speed and lands
    singular values in ~[0.7, 1.3]; this one is used where true
    orthogonality matters (EmbProj initialization, rotation matrices).
    """
    x = g.astype(jnp.float32)
    transposed = x.shape[0] > x.shape[1]
    if transposed:
        x = x.T
    x = x / (jnp.sqrt(jnp.sum(x * x)) + eps)
    for _ in range(steps):
        x = 1.5 * x - 0.5 * (x @ x.T) @ x
    if transposed:
        x = x.T
    return x


def ssnorm_ref(x, gamma, eps=1e-6):
    """Single-Scale RMSNorm (paper Eq. 3): gamma * x / ||x||_2 (last axis).

    gamma is a single scalar; there is no per-channel scale, hence no
    privileged basis. Initialized to sqrt(d) so t=0 behaviour matches
    RMSNorm with unit scales.
    """
    norm = jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2, axis=-1,
                            keepdims=True) + eps)
    return gamma * x / norm


def rmsnorm_ref(x, scale, eps=1e-6):
    """Standard RMSNorm with per-channel learnable scale (the baseline)."""
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return x * (scale / jnp.sqrt(ms + eps))


def fake_quant_ref(x, levels, axis=-1, eps=1e-8):
    """Symmetric round-to-nearest quantize-dequantize.

    levels = 2**(bits-1) - 1 (e.g. 7 for 4-bit). The scale is dynamic
    absmax along `axis` (per-token for activations when axis=-1; pass
    axis=None for per-tensor). `levels` may be a traced scalar, which is
    how the evalq artifact exposes bit-width as a runtime input.
    """
    x = x.astype(jnp.float32)
    if axis is None:
        absmax = jnp.max(jnp.abs(x))
    else:
        absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = absmax / levels + eps
    q = jnp.clip(jnp.round(x / scale), -levels - 1, levels)
    return q * scale


def pow2_block(n: int) -> int:
    """Largest power of two dividing n (the Hadamard block size)."""
    return n & (-n)


def hadamard_ref(x):
    """Normalized blocked fast Walsh-Hadamard transform along the last axis.

    For n = m * 2^k (2^k the largest power-of-two factor), applies the
    normalized FWHT independently to each 2^k-sized block — i.e. multiplies
    by the block-diagonal orthogonal matrix I_m (x) H_{2^k}. This is how
    QuaRot-style online rotations handle non-power-of-two hidden sizes.
    Involution: had(had(x)) == x.
    """
    n = x.shape[-1]
    blk = pow2_block(n)
    orig_shape = x.shape
    y = x.astype(jnp.float32).reshape(-1, blk)
    h = 1
    while h < blk:
        y = y.reshape(-1, blk // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    y = y.reshape(orig_shape) / jnp.sqrt(jnp.float32(blk))
    return y.astype(jnp.float32)


def excess_kurtosis_ref(x, eps=1e-12):
    """Excess kurtosis E[((x-mu)/sigma)^4] - 3 over all elements (Eq. 4)."""
    x = x.astype(jnp.float32).reshape(-1)
    mu = jnp.mean(x)
    var = jnp.mean((x - mu) ** 2)
    m4 = jnp.mean((x - mu) ** 4)
    return m4 / (var ** 2 + eps) - 3.0
