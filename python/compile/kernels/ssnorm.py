"""Pallas kernel for Single-Scale RMSNorm (paper Eq. 3).

SSNorm(x) = gamma * x / ||x||_2 along the channel axis, with a *scalar*
learnable gamma — the architectural fix that removes RMSNorm's per-channel
scale vector (a privileged basis, Section 3.2).

BlockSpec: the row dimension is tiled, the channel dimension stays whole in
VMEM (d <= 1024 here; one row-block of 128 x d f32 is <= 512 KiB), so the
L2-norm reduction is a single in-VMEM pass per row.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ssnorm_kernel(x_ref, gamma_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = gamma_ref[0] * x / norm


def _pick_rows(rows: int, target: int = 128) -> int:
    if rows <= target:
        return rows
    for cand in range(target, 0, -1):
        if rows % cand == 0:
            return cand
    return rows


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _ssnorm_pallas(x2d, gamma, eps, interpret=True):
    rows, d = x2d.shape
    br = _pick_rows(rows)
    return pl.pallas_call(
        functools.partial(_ssnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=interpret,
    )(x2d.astype(jnp.float32), jnp.reshape(gamma, (1,)).astype(jnp.float32))


def ssnorm(x, gamma, eps=1e-6, use_pallas=True):
    """SSNorm over the last axis of an arbitrary-rank input."""
    if not use_pallas:
        return ref.ssnorm_ref(x, gamma, eps=eps)
    shape = x.shape
    out = _ssnorm_pallas(x.reshape(-1, shape[-1]), gamma, eps)
    return out.reshape(shape)
