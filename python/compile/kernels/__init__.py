"""L1 Pallas kernels (build-time only; lowered with interpret=True).

Kernel selection: every wrapper takes `use_pallas`; the package-level
default comes from the OSP_KERNELS env var ("pallas" | "jnp") so aot.py
can build both artifact flavors without touching call sites. The jnp
flavor routes to the oracles in ref.py — the two flavors are asserted
numerically identical by python/tests/test_kernels.py.
"""

import os

DEFAULT_USE_PALLAS = os.environ.get("OSP_KERNELS", "pallas") == "pallas"

from .ref import (  # noqa: E402,F401
    NS_COEFFS,
    NS_STEPS,
    excess_kurtosis_ref,
    fake_quant_ref,
    hadamard_ref,
    matmul_ref,
    ns_orthogonalize_ref,
    rmsnorm_ref,
    ssnorm_ref,
)
from .newton_schulz import matmul_pallas, ns_orthogonalize  # noqa: E402,F401
from .ssnorm import ssnorm  # noqa: E402,F401
from .fake_quant import fake_quant  # noqa: E402,F401
from .hadamard import hadamard  # noqa: E402,F401
