"""Tiled Pallas GEMM + Muon's Newton-Schulz orthogonalization (L1 hot-spot).

The NS iteration is 10 chained square GEMMs per gradient matrix per step,
so the kernel of interest is a blocked matmul. The BlockSpec is MXU-shaped
(128x128 output tiles, fp32 accumulation over a K-grid) — see DESIGN.md
§Hardware-Adaptation for the TPU mapping; on this testbed it runs under
interpret=True.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import NS_COEFFS, NS_STEPS

# MXU-shaped tile. VMEM per grid step: 3 tiles * 128*128 * 4B = 192 KiB.
TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j].

    The K axis is the innermost grid dimension, so o_ref revisits the same
    tile across k steps — initialize on k == 0, accumulate afterwards.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def _pick_block(dim: int, target: int = TILE) -> int:
    """Largest divisor of `dim` that is <= target (prefer MXU-sized)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_pallas(a, b, interpret=True):
    """Blocked matmul a @ b via Pallas. Shapes need not be tile-aligned —
    non-divisible dims fall back to the largest divisor block."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims mismatch: {a.shape} @ {b.shape}"
    bm, bn, bk = _pick_block(m), _pick_block(n), _pick_block(k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def ns_orthogonalize(g, steps=NS_STEPS, coeffs=NS_COEFFS, eps=1e-7,
                     use_pallas=True):
    """Newton-Schulz orthogonalization G = U S V^T -> ~U V^T (paper Eq. 2).

    Identical math to ref.ns_orthogonalize_ref but with every GEMM routed
    through the Pallas tile kernel when use_pallas is set.
    """
    if not use_pallas:
        return ref.ns_orthogonalize_ref(g, steps=steps, coeffs=coeffs,
                                        eps=eps)
    mm = matmul_pallas
    a, b, c = coeffs
    x = g.astype(jnp.float32)
    transposed = x.shape[0] > x.shape[1]
    if transposed:
        x = x.T
    x = x / (jnp.sqrt(jnp.sum(x * x)) + eps)
    for _ in range(steps):
        gram = mm(x, x.T)
        poly = b * gram + c * mm(gram, gram)
        x = a * x + mm(poly, x)
    if transposed:
        x = x.T
    return x
