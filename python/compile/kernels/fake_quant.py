"""Pallas kernel for symmetric round-to-nearest quantize-dequantize (RTN).

Per-row (per-token) dynamic quantization: scale = absmax(row) / levels.
`levels` (= 2**(bits-1) - 1) is a *runtime* input so a single lowered
artifact serves the whole Figure-4 bit-width sweep; passing levels large
enough (e.g. 2**20) makes the op numerically the identity, which is how
the 16-bit (unquantized) columns are expressed.

The absmax reduction and the quantize step are fused into one kernel pass
per row-block (two-phase within the block), so HBM traffic is exactly one
read + one write of the tensor.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fake_quant_kernel(x_ref, levels_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    levels = levels_ref[0]
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = absmax / levels + eps
    q = jnp.clip(jnp.round(x / scale), -levels - 1.0, levels)
    o_ref[...] = q * scale


def _pick_rows(rows: int, target: int = 128) -> int:
    if rows <= target:
        return rows
    for cand in range(target, 0, -1):
        if rows % cand == 0:
            return cand
    return rows


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def _fake_quant_pallas(x2d, levels, eps, interpret=True):
    rows, d = x2d.shape
    br = _pick_rows(rows)
    return pl.pallas_call(
        functools.partial(_fake_quant_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=interpret,
    )(x2d.astype(jnp.float32),
      jnp.reshape(jnp.asarray(levels, jnp.float32), (1,)))


def fake_quant(x, levels, axis=-1, eps=1e-8, use_pallas=True):
    """RTN quantize-dequantize along `axis` (only axis=-1 has a Pallas
    path; other axes route to the oracle)."""
    if not use_pallas or axis != -1:
        return ref.fake_quant_ref(x, levels, axis=axis, eps=eps)
    shape = x.shape
    out = _fake_quant_pallas(x.reshape(-1, shape[-1]), levels, eps)
    return out.reshape(shape)
