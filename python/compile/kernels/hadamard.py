"""Pallas kernel for the normalized fast Walsh-Hadamard transform.

Used for the online "FFN Had" rotation (Table 2/4): the FFN hidden state
is rotated by H before quantization and the down-projection weight is
pre-rotated by H on the Rust side, so the composition is exact in fp32
(H is orthogonal and an involution after normalization).

The butterfly runs entirely in VMEM on a row-block: log2(n) stages of
stride-halving add/sub, one HBM read + one write total.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _hadamard_kernel(x_ref, o_ref, *, n, blk):
    y = x_ref[...].astype(jnp.float32)
    rows = y.shape[0]
    y = y.reshape(-1, blk)
    h = 1
    while h < blk:
        y = y.reshape(-1, blk // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    o_ref[...] = y.reshape(rows, n) / jnp.sqrt(jnp.float32(blk))


def _pick_rows(rows: int, target: int = 128) -> int:
    if rows <= target:
        return rows
    for cand in range(target, 0, -1):
        if rows % cand == 0:
            return cand
    return rows


@functools.partial(jax.jit, static_argnames=("interpret",))
def _hadamard_pallas(x2d, interpret=True):
    rows, n = x2d.shape
    br = _pick_rows(rows)
    return pl.pallas_call(
        functools.partial(_hadamard_kernel, n=n, blk=ref.pow2_block(n)),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=interpret,
    )(x2d.astype(jnp.float32))


def hadamard(x, use_pallas=True):
    """Normalized blocked FWHT along the last axis (block = largest
    power-of-two factor of the axis length; see ref.hadamard_ref)."""
    n = x.shape[-1]
    if not use_pallas:
        return ref.hadamard_ref(x)
    shape = x.shape
    out = _hadamard_pallas(x.reshape(-1, n))
    return out.reshape(shape)
