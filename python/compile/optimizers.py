"""Optimizers for the train_step artifacts: Adam, Muon (+decoupled Adam
embeddings), Muon-everywhere, Shampoo, and a SOAP-like method.

All are pure jnp (matmul-only linear algebra — no eigh/qr custom-calls,
which the runtime's XLA 0.5.1 CPU client could not execute). Shampoo's
inverse fourth root uses a coupled Newton iteration, the same strategy
production TPU Shampoo uses; the SOAP variant tracks the Shampoo
eigenbasis by subspace iteration with Newton-Schulz polar
orthogonalization (documented approximation — SOAP is only exercised by
the Table-1 cost benchmark, which measures cost structure, not quality).

State layout is a flat dict (name -> array) whose ordered spec is exported
to artifacts/manifest.json; the Rust coordinator allocates and threads it.
"""

from typing import Dict, List, Tuple

import jax.numpy as jnp

from .config import ModelConfig
from .kernels.newton_schulz import ns_orthogonalize
from .model import param_specs

# Hyperparameters (paper Appendix A.1: wd = 0.01 everywhere; Adam lr is
# 10x the Muon lr — we thread one runtime `lr` and scale internally).
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8
MUON_MOMENTUM = 0.95
WEIGHT_DECAY = 0.01
ADAM_LR_RATIO = 10.0   # lr_adam = ADAM_LR_RATIO * lr when inside Muon
SHAMPOO_EPS = 1e-6
SHAMPOO_MOMENTUM = 0.9

OPTIMIZERS = ("adam", "muon", "muon_noadam", "shampoo", "soap")


def _partition(opt_name: str, cfg: ModelConfig):
    """Which params get the matrix treatment vs element-wise Adam."""
    matrix, elementwise = [], []
    for s in param_specs(cfg):
        is_matrix = s.kind == "matrix" or (
            opt_name == "muon_noadam" and s.kind in ("embed", "unembed"))
        if opt_name in ("muon", "muon_noadam", "shampoo", "soap") and is_matrix:
            matrix.append(s)
        else:
            elementwise.append(s)
    return matrix, elementwise


def opt_state_specs(opt_name: str, cfg: ModelConfig) -> List[Tuple[str, tuple, str]]:
    """Ordered (name, shape, init) opt-state leaves; init is zeros|eye."""
    matrix, elementwise = _partition(opt_name, cfg)
    specs: List[Tuple[str, tuple, str]] = [("step", (1,), "zeros")]
    for s in elementwise:
        specs.append((f"adam_m.{s.name}", s.shape, "zeros"))
        specs.append((f"adam_v.{s.name}", s.shape, "zeros"))
    for s in matrix:
        if opt_name in ("muon", "muon_noadam"):
            specs.append((f"muon_buf.{s.name}", s.shape, "zeros"))
        elif opt_name == "shampoo":
            m, n = s.shape
            specs.append((f"sh_buf.{s.name}", s.shape, "zeros"))
            specs.append((f"sh_l.{s.name}", (m, m), "zeros"))
            specs.append((f"sh_r.{s.name}", (n, n), "zeros"))
        elif opt_name == "soap":
            m, n = s.shape
            specs.append((f"so_l.{s.name}", (m, m), "zeros"))
            specs.append((f"so_r.{s.name}", (n, n), "zeros"))
            specs.append((f"so_ql.{s.name}", (m, m), "eye"))
            specs.append((f"so_qr.{s.name}", (n, n), "eye"))
            specs.append((f"so_m.{s.name}", s.shape, "zeros"))
            specs.append((f"so_v.{s.name}", s.shape, "zeros"))
        elif opt_name == "adam":
            pass  # handled element-wise
    return specs


def init_opt_state(opt_name: str, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    state = {}
    for name, shape, init in opt_state_specs(opt_name, cfg):
        if init == "eye":
            state[name] = jnp.eye(shape[0], dtype=jnp.float32)
        else:
            state[name] = jnp.zeros(shape, jnp.float32)
    return state


# ---------------------------------------------------------------------------
# Element-wise Adam (bias-corrected, decoupled weight decay)
# ---------------------------------------------------------------------------

def _adam_leaf(p, g, m, v, lr, t, wd):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1 ** t)
    vhat = v / (1.0 - ADAM_B2 ** t)
    p = p * (1.0 - lr * wd) - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return p, m, v


# ---------------------------------------------------------------------------
# Matrix preconditioners
# ---------------------------------------------------------------------------

def _muon_update(g, buf, use_pallas):
    """Nesterov momentum + Newton-Schulz orthogonalization + shape scale
    (Jordan et al. 2024): u = ns(g + mu*buf) * sqrt(max(1, n_out/n_in))."""
    buf = MUON_MOMENTUM * buf + g
    u = ns_orthogonalize(g + MUON_MOMENTUM * buf, use_pallas=use_pallas)
    n_in, n_out = g.shape
    u = u * jnp.sqrt(jnp.maximum(1.0, n_out / n_in))
    return u, buf


def _inv_fourth_root(a, iters=8, eps=SHAMPOO_EPS):
    """A^{-1/4} for symmetric PSD A via the coupled Newton iteration
    (matmul-only; the production TPU-Shampoo approach)."""
    n = a.shape[0]
    eye = jnp.eye(n, dtype=jnp.float32)
    # Normalize by the Frobenius norm (an upper bound on lambda_max) so the
    # iteration's spectrum starts inside (0, 1], its convergence region.
    c = jnp.sqrt(jnp.sum(a * a)) + eps
    m = a / c + eps * eye
    x = eye
    for _ in range(iters):
        t = (5.0 * eye - m) / 4.0
        x = x @ t
        t2 = t @ t
        m = (t2 @ t2) @ m
    return x / (c ** 0.25)


def _shampoo_update(g, buf, l_stat, r_stat):
    l_stat = l_stat + g @ g.T
    r_stat = r_stat + g.T @ g
    pre = _inv_fourth_root(l_stat) @ g @ _inv_fourth_root(r_stat)
    # Grafting: give the preconditioned direction the raw gradient's norm.
    gn = jnp.sqrt(jnp.sum(g * g))
    pn = jnp.sqrt(jnp.sum(pre * pre)) + 1e-12
    u = pre * (gn / pn)
    buf = SHAMPOO_MOMENTUM * buf + u
    return buf, buf, l_stat, r_stat


def _soap_update(g, l_stat, r_stat, ql, qr, m, v, t, use_pallas):
    l_stat = 0.95 * l_stat + 0.05 * (g @ g.T)
    r_stat = 0.95 * r_stat + 0.05 * (g.T @ g)
    # One subspace-iteration step toward the stats' eigenbasis, kept
    # orthogonal by Newton-Schulz polar factorization.
    ql = ns_orthogonalize(l_stat @ ql, use_pallas=use_pallas)
    qr = ns_orthogonalize(r_stat @ qr, use_pallas=use_pallas)
    g_rot = ql.T @ g @ qr
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g_rot
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g_rot * g_rot
    mhat = m / (1.0 - ADAM_B1 ** t)
    vhat = v / (1.0 - ADAM_B2 ** t)
    u_rot = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    u = ql @ u_rot @ qr.T
    return u, l_stat, r_stat, ql, qr, m, v


# ---------------------------------------------------------------------------
# The single-step update entry point
# ---------------------------------------------------------------------------

def opt_update(opt_name: str, cfg: ModelConfig, params: Dict, grads: Dict,
               state: Dict, lr, use_pallas: bool = True):
    """Apply one optimizer step. lr is a runtime scalar (the Rust
    coordinator owns the trapezoidal schedule). Returns (params', state')."""
    assert opt_name in OPTIMIZERS, opt_name
    matrix, elementwise = _partition(opt_name, cfg)
    new_p, new_s = {}, {}
    t = state["step"][0] + 1.0
    new_s["step"] = state["step"] + 1.0

    lr_adam = lr * ADAM_LR_RATIO if opt_name != "adam" else lr
    for s in elementwise:
        wd = WEIGHT_DECAY if s.kind != "norm" else 0.0
        p, m, v = _adam_leaf(params[s.name], grads[s.name],
                             state[f"adam_m.{s.name}"],
                             state[f"adam_v.{s.name}"], lr_adam, t, wd)
        new_p[s.name] = p
        new_s[f"adam_m.{s.name}"] = m
        new_s[f"adam_v.{s.name}"] = v

    for s in matrix:
        p, g = params[s.name], grads[s.name]
        if opt_name in ("muon", "muon_noadam"):
            u, buf = _muon_update(g, state[f"muon_buf.{s.name}"], use_pallas)
            new_s[f"muon_buf.{s.name}"] = buf
        elif opt_name == "shampoo":
            u, buf, l_stat, r_stat = _shampoo_update(
                g, state[f"sh_buf.{s.name}"], state[f"sh_l.{s.name}"],
                state[f"sh_r.{s.name}"])
            new_s[f"sh_buf.{s.name}"] = buf
            new_s[f"sh_l.{s.name}"] = l_stat
            new_s[f"sh_r.{s.name}"] = r_stat
        elif opt_name == "soap":
            u, l_stat, r_stat, ql, qr, m, v = _soap_update(
                g, state[f"so_l.{s.name}"], state[f"so_r.{s.name}"],
                state[f"so_ql.{s.name}"], state[f"so_qr.{s.name}"],
                state[f"so_m.{s.name}"], state[f"so_v.{s.name}"], t,
                use_pallas)
            new_s[f"so_l.{s.name}"] = l_stat
            new_s[f"so_r.{s.name}"] = r_stat
            new_s[f"so_ql.{s.name}"] = ql
            new_s[f"so_qr.{s.name}"] = qr
            new_s[f"so_m.{s.name}"] = m
            new_s[f"so_v.{s.name}"] = v
        else:
            raise AssertionError(opt_name)
        new_p[s.name] = p * (1.0 - lr * WEIGHT_DECAY) - lr * u

    return new_p, new_s
